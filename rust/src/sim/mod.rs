//! Latency evaluation of a [`Trace`](crate::trace::Trace) under a FIFO
//! depth configuration.
//!
//! Two independent implementations of the same cycle semantics:
//!
//! - [`fast`] — the production engine (LightningSim phase-2 analog):
//!   event-driven commit-time propagation, O(total trace ops) per cold
//!   configuration and O(dirty region) per *delta* — the simulator
//!   retains the committed schedule between calls and replays only what
//!   a depth change can affect (see the [`fast`] module docs for the
//!   invalidation rules). Zero allocation in the hot loop after
//!   construction.
//! - [`golden`] — a deliberately simple global-time-stepped simulator used
//!   as the accuracy reference (the paper's C/RTL co-simulation role in
//!   Table II). Slower, structurally different, obviously correct.
//!
//! [`cosim`] models the *runtime* of traditional HLS/RTL co-simulation for
//! the Table III comparisons. [`scenario`] lifts [`fast`] from one trace
//! to a multi-trace [`Workload`](crate::trace::workload::Workload): one
//! retained-schedule [`FastSim`] per scenario, worst-case/weighted
//! latency aggregation, deadlock-in-any-scenario infeasibility, and
//! max-merged channel statistics.
//!
//! # Cycle semantics (shared by both simulators)
//!
//! - A process executes its trace ops in order at initiation interval 1:
//!   op `k` may start no earlier than `commit(k-1) + 1 + delay(k)`; the
//!   first op no earlier than `delay(0)`.
//! - A **write** as the `j`-th write on channel `c` with depth `d` commits
//!   at `max(start, rd_commit[j-d] + 1)` (the FIFO holds at most `d`
//!   unread tokens; a slot frees the cycle after its read commits); if
//!   `j < d` there is no constraint.
//! - A **read** as the `j`-th read on `c` commits at
//!   `max(start, wr_commit[j] + rl)` where the read latency `rl` is 1 for
//!   shift-register FIFOs and 2 for BRAM-backed FIFOs (paper footnote 2:
//!   SRL FIFOs save one read cycle, which is why shrinking FIFOs can
//!   *slightly beat* Baseline-Max latency).
//! - Design latency = max over processes of (last commit + 1 + trailing
//!   compute delay).
//! - A configuration **deadlocks** iff the commit fixpoint leaves some
//!   process blocked forever.

pub mod cosim;
pub mod fast;
pub mod golden;
pub mod scenario;

pub use fast::{FastSim, RunInfo, SimOutcome};
pub use scenario::ScenarioSim;

/// Read latency (cycles from write commit to earliest read commit) for a
/// FIFO of the given shape under the given depth.
#[inline]
pub fn read_latency(depth: u32, width_bits: u32, uniform: bool) -> u64 {
    if uniform || crate::bram::is_srl(depth, width_bits) {
        1
    } else {
        2
    }
}

/// Simulator options shared by [`fast`] and [`golden`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SimOptions {
    /// Use read latency 1 for every FIFO regardless of implementation
    /// (disables the SRL/BRAM distinction). Used by property tests, where
    /// it makes latency monotonically non-increasing in depths.
    pub uniform_read_latency: bool,
}
