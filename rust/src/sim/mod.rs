//! Latency evaluation of a [`Trace`](crate::trace::Trace) under a FIFO
//! depth configuration.
//!
//! Three independent implementations of the same cycle semantics:
//!
//! - [`fast`] — the production engine (LightningSim phase-2 analog):
//!   event-driven commit-time propagation, O(total trace ops) per cold
//!   configuration and O(dirty region) per *delta* — the simulator
//!   retains the committed schedule between calls and replays only what
//!   a depth change can affect (see the [`fast`] module docs for the
//!   invalidation rules). Zero allocation in the hot loop after
//!   construction.
//! - [`compiled`] — the graph-compiled engine (LightningSimV2 analog):
//!   the trace is lowered **once** into a static event graph (nodes =
//!   channel op commits; edges = intra-process program order +
//!   cross-process full/empty FIFO constraints parameterized by depth),
//!   and each configuration is evaluated as a longest-path propagation
//!   over that graph, with depth-edge-only invalidation for incremental
//!   re-evaluation.
//! - [`batched`] — the lane-batched SoA evaluator over the same compiled
//!   event graph: K depth vectors per Kahn walk, with lane-major node
//!   times, lane-parameterized full-FIFO edges, and per-lane deadlock
//!   detection and blocked-set recovery. Answers a whole optimizer batch
//!   in one traversal of the graph tables.
//! - [`golden`] — a deliberately simple global-time-stepped simulator used
//!   as the accuracy reference (the paper's C/RTL co-simulation role in
//!   Table II). Slower, structurally different, obviously correct.
//!
//! [`fast`], [`compiled`] and [`batched`] all implement the
//! [`SimBackend`] trait and are interchangeable everywhere above this
//! module ([`scenario`], the DSE engine, the CLI's
//! `--backend {fast,compiled,batched}`); the
//! `tests/backend_conformance.rs` suite pins them bit-identical to each
//! other (full outcomes, incl. deadlock blocked sets — per lane for the
//! batched backend) and latency-exact against [`golden`].
//!
//! [`cosim`] models the *runtime* of traditional HLS/RTL co-simulation for
//! the Table III comparisons. [`scenario`] lifts any [`SimBackend`] from
//! one trace to a multi-trace [`Workload`](crate::trace::workload::Workload):
//! one retained-schedule backend instance per scenario, worst-case/weighted
//! latency aggregation, deadlock-in-any-scenario infeasibility, and
//! max-merged channel statistics.
//!
//! # Cycle semantics (shared by all simulators)
//!
//! - A process executes its trace ops in order at initiation interval 1:
//!   op `k` may start no earlier than `commit(k-1) + 1 + delay(k)`; the
//!   first op no earlier than `delay(0)`.
//! - A **write** as the `j`-th write on channel `c` with depth `d` commits
//!   at `max(start, rd_commit[j-d] + 1)` (the FIFO holds at most `d`
//!   unread tokens; a slot frees the cycle after its read commits); if
//!   `j < d` there is no constraint.
//! - A **read** as the `j`-th read on `c` commits at
//!   `max(start, wr_commit[j] + rl)` where the read latency `rl` is 1 for
//!   shift-register FIFOs and 2 for BRAM-backed FIFOs (paper footnote 2:
//!   SRL FIFOs save one read cycle, which is why shrinking FIFOs can
//!   *slightly beat* Baseline-Max latency).
//! - Design latency = max over processes of (last commit + 1 + trailing
//!   compute delay).
//! - A configuration **deadlocks** iff the commit fixpoint leaves some
//!   process blocked forever.

pub mod batched;
pub mod compiled;
pub mod cosim;
pub mod fast;
pub mod golden;
pub mod scenario;

pub use batched::BatchedSim;
pub use compiled::CompiledSim;
pub use fast::{FastSim, RunInfo, SimOutcome};
pub use scenario::ScenarioSim;

use crate::trace::Trace;
use fast::ChannelStats;
use std::sync::Arc;

/// Read latency (cycles from write commit to earliest read commit) for a
/// FIFO of the given shape under the given depth.
#[inline]
pub fn read_latency(depth: u32, width_bits: u32, uniform: bool) -> u64 {
    if uniform || crate::bram::is_srl(depth, width_bits) {
        1
    } else {
        2
    }
}

/// Simulator options shared by [`fast`], [`compiled`] and [`golden`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SimOptions {
    /// Use read latency 1 for every FIFO regardless of implementation
    /// (disables the SRL/BRAM distinction). Used by property tests, where
    /// it makes latency monotonically non-increasing in depths.
    pub uniform_read_latency: bool,
}

/// The shared delta-invalidation core both retained-schedule backends
/// run before an incremental re-evaluation: seed per-process checkpoints
/// from the dirty channel set (writes from ordinal `min(d0, d1)`; every
/// read on an SRL↔BRAM read-latency flip, detected against the retained
/// `rd_lat`), then propagate to a fixpoint over [`ChanOpIndex`]
/// (checkpoints only ever decrease, so the worklist terminates).
///
/// On return `ckpt[p]` is the earliest op index of process `p` whose
/// commit time can change under `depths`; the caller owns the cost gate
/// and the rewind. Returns the number of dirty channels (0 = identical
/// configuration; `ckpt` is all-`len` and `wl` untouched in that case).
/// Keeping this in ONE place is deliberate: a divergence in the
/// invalidation rule between backends would break their bit-identity in
/// ways only warm multi-mutation chains can expose.
#[allow(clippy::too_many_arguments)]
pub(crate) fn delta_checkpoints(
    trace: &Trace,
    index: &crate::trace::ChanOpIndex,
    last_depths: &[u32],
    depths: &[u32],
    rd_lat: &[u64],
    widths: &[u32],
    uniform: bool,
    ckpt: &mut [u32],
    wl: &mut Vec<u32>,
    in_wl: &mut [bool],
) -> u32 {
    let nch = trace.channels.len();
    let nproc = trace.ops.len();
    for p in 0..nproc {
        ckpt[p] = trace.ops[p].len() as u32;
    }
    let mut n_dirty = 0u32;
    for ch in 0..nch {
        let d0 = last_depths[ch];
        let d1 = depths[ch];
        if d0 == d1 {
            continue;
        }
        n_dirty += 1;
        // Writes from ordinal min(d0, d1) see a different full-FIFO
        // constraint.
        let w0 = d0.min(d1) as usize;
        if let Some(&op_i) = index.wr_ops[ch].get(w0) {
            let w = index.writer[ch] as usize;
            ckpt[w] = ckpt[w].min(op_i);
        }
        // An SRL↔BRAM crossing changes the latency of every read.
        let rl1 = read_latency(d1, widths[ch], uniform);
        if rl1 != rd_lat[ch] {
            if let Some(&op_i) = index.rd_ops[ch].first() {
                let r = index.reader[ch] as usize;
                ckpt[r] = ckpt[r].min(op_i);
            }
        }
    }
    if n_dirty == 0 {
        return 0;
    }
    wl.clear();
    for p in 0..nproc {
        let invalidated = (ckpt[p] as usize) < trace.ops[p].len();
        in_wl[p] = invalidated;
        if invalidated {
            wl.push(p as u32);
        }
    }
    while let Some(p) = wl.pop() {
        let p = p as usize;
        in_wl[p] = false;
        let k = ckpt[p];
        for &chu in index.proc_chans[p].iter() {
            let ch = chu as usize;
            if index.writer[ch] as usize == p {
                // Writes on `ch` from op index `k` are invalid; read `j`
                // waits on write `j`.
                let w_inv = index.wr_ops[ch].partition_point(|&i| i < k);
                if let Some(&op_i) = index.rd_ops[ch].get(w_inv) {
                    let r = index.reader[ch] as usize;
                    if op_i < ckpt[r] {
                        ckpt[r] = op_i;
                        if !in_wl[r] {
                            in_wl[r] = true;
                            wl.push(r as u32);
                        }
                    }
                }
            }
            if index.reader[ch] as usize == p {
                // Reads from ordinal `r_inv` are invalid; write `j` waits
                // on read `j - d1` freeing its slot.
                let r_inv = index.rd_ops[ch].partition_point(|&i| i < k);
                let target = r_inv as u64 + depths[ch] as u64;
                if (target as usize as u64) == target
                    && (target as usize) < index.wr_ops[ch].len()
                {
                    let op_i = index.wr_ops[ch][target as usize];
                    let w = index.writer[ch] as usize;
                    if op_i < ckpt[w] {
                        ckpt[w] = op_i;
                        if !in_wl[w] {
                            in_wl[w] = true;
                            wl.push(w as u32);
                        }
                    }
                }
            }
        }
    }
    n_dirty
}

/// Trace ops at or past their process's checkpoint — the numerator of
/// the shared incremental cost gate.
pub(crate) fn invalid_ops(trace: &Trace, ckpt: &[u32]) -> u64 {
    trace
        .ops
        .iter()
        .zip(ckpt)
        .map(|(ops, &c)| (ops.len() as u64).saturating_sub(c as u64))
        .sum()
}

/// A single-trace simulation backend: everything [`ScenarioSim`] (and
/// through it the DSE engine) needs from a simulator. Implemented by
/// [`FastSim`] (event-driven, the default), [`CompiledSim`]
/// (graph-compiled) and [`BatchedSim`] (lane-batched SoA); all must be
/// **bit-identical** — same latencies, same deadlock verdicts, same
/// blocked sets — on every trace and depth vector, which
/// `tests/backend_conformance.rs` enforces. Backends are `Send` (never
/// `Sync`-shared): each worker thread owns its own clone, including its
/// own retained schedule.
pub trait SimBackend: Send {
    /// Short backend name for reports (`"fast"` / `"compiled"` /
    /// `"batched"`).
    fn name(&self) -> &'static str;
    /// The trace this backend evaluates.
    fn trace(&self) -> &Arc<Trace>;
    /// Evaluate one FIFO depth configuration.
    fn simulate(&mut self, depths: &[u32]) -> SimOutcome;
    /// Evaluate and collect per-channel occupancy/stall statistics into a
    /// caller-owned buffer.
    fn simulate_with_stats_into(&mut self, depths: &[u32], stats: &mut ChannelStats) -> SimOutcome;
    /// Evaluate a batch of configurations, returning each lane's outcome
    /// and telemetry in input order. The default implementation is a loop
    /// of [`simulate`](Self::simulate) — the retained-schedule backends
    /// ([`FastSim`], [`CompiledSim`]) are unchanged by batching and still
    /// delta-replay between consecutive lanes — while [`BatchedSim`]
    /// overrides it with a single lane-packed SoA Kahn walk.
    fn eval_batch(&mut self, configs: &[Box<[u32]>]) -> Vec<(SimOutcome, RunInfo)> {
        configs
            .iter()
            .map(|c| {
                let out = self.simulate(c);
                (out, self.last_run())
            })
            .collect()
    }
    /// Telemetry of the most recent call.
    fn last_run(&self) -> RunInfo;
    /// Enable/disable schedule retention and incremental re-evaluation.
    fn set_incremental(&mut self, on: bool);
    /// Clone into a boxed trait object (worker-pool fan-out).
    fn clone_box(&self) -> Box<dyn SimBackend>;
}

impl Clone for Box<dyn SimBackend> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// Which [`SimBackend`] implementation to instantiate — threaded from the
/// CLI's `--backend {fast,compiled,batched}` / sweep `"backend"` key
/// through [`crate::dse::EvalEngine`] and [`ScenarioSim`] down to every
/// worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// The event-driven [`FastSim`] (default).
    #[default]
    Fast,
    /// The graph-compiled [`CompiledSim`].
    Compiled,
    /// The lane-batched SoA [`BatchedSim`].
    Batched,
}

/// Every backend name [`BackendKind::parse`] accepts, for error messages
/// and help text.
pub const BACKEND_NAMES: &str = "fast, compiled, batched";

impl BackendKind {
    /// Parse a CLI/sweep backend name. The error names every valid value.
    pub fn parse(s: &str) -> Result<BackendKind, String> {
        match s {
            "fast" => Ok(BackendKind::Fast),
            "compiled" => Ok(BackendKind::Compiled),
            "batched" => Ok(BackendKind::Batched),
            _ => Err(format!("unknown backend '{s}' (expected one of: {BACKEND_NAMES})")),
        }
    }

    /// The backend's report name.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Fast => "fast",
            BackendKind::Compiled => "compiled",
            BackendKind::Batched => "batched",
        }
    }

    /// Instantiate a backend over one trace.
    pub fn build(self, trace: Arc<Trace>, opts: SimOptions) -> Box<dyn SimBackend> {
        match self {
            BackendKind::Fast => Box::new(FastSim::with_options(trace, opts)),
            BackendKind::Compiled => Box::new(CompiledSim::with_options(trace, opts)),
            BackendKind::Batched => Box::new(BatchedSim::with_options(trace, opts)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses_and_names() {
        assert_eq!(BackendKind::parse("fast"), Ok(BackendKind::Fast));
        assert_eq!(BackendKind::parse("compiled"), Ok(BackendKind::Compiled));
        assert_eq!(BackendKind::parse("batched"), Ok(BackendKind::Batched));
        assert_eq!(BackendKind::default(), BackendKind::Fast);
        assert_eq!(BackendKind::Fast.name(), "fast");
        assert_eq!(BackendKind::Compiled.name(), "compiled");
        assert_eq!(BackendKind::Batched.name(), "batched");
        // Satellite: the parse error names every valid backend.
        let err = BackendKind::parse("nope").unwrap_err();
        for name in ["fast", "compiled", "batched"] {
            assert!(err.contains(name), "error must name '{name}': {err}");
        }
    }
}
