//! The scenario-bank simulator: one retained-schedule [`SimBackend`] per
//! workload scenario, evaluated together.
//!
//! [`ScenarioSim`] is the multi-trace counterpart of a single-trace
//! simulator: it owns one backend instance per scenario of a
//! [`Workload`] — [`FastSim`] by default, or any other [`SimBackend`]
//! (the graph-compiled [`CompiledSim`](super::CompiledSim) via
//! [`BackendKind`]) — so the delta-incremental replay of each scenario's
//! retained schedule still applies *per scenario*: a 1-channel DSE
//! mutation re-simulates as a cheap delta in every scenario's bank
//! member, not just one. A configuration's outcome is aggregated across
//! scenarios:
//!
//! - **deadlock in any scenario** makes the configuration infeasible
//!   (the blocked sets are unioned for diagnostics);
//! - otherwise the latency is the worst-case (default) or weighted mean
//!   over scenarios ([`Aggregation`]);
//! - per-channel occupancy/stall statistics are **max-merged** across
//!   scenarios, so the greedy ranking and the targeted Vitis hunter see
//!   each channel's worst observed pressure.
//!
//! Single-scenario banks take the exact single-trace fast path: outcome,
//! statistics and [`RunInfo`] telemetry are bit-identical to calling the
//! underlying [`FastSim`] directly, with no extra allocation or
//! aggregation work (`tests/workload_equivalence.rs` enforces this).
//!
//! [`eval_latency`](ScenarioSim::eval_latency) is the engine's
//! latency-only fast path: since deadlock in any scenario is already
//! infeasible, it can probe scenarios in descending
//! recent-deadlock-frequency order and stop at the first failure,
//! skipping the remaining replays. The full blocked-set union stays on
//! [`simulate`](ScenarioSim::simulate) and the stats path, so CLI
//! diagnostics are unchanged.

use super::fast::{BlockInfo, ChannelStats, FastSim, RunInfo, SimOutcome};
use super::{BackendKind, SimBackend, SimOptions};
use crate::opt::objective::{aggregate_latency, Aggregation};
use crate::trace::workload::Workload;
use crate::trace::Trace;
use std::sync::Arc;

/// Per-lane result of one [`ScenarioSim::eval_batch`] call: the
/// workload-aggregated latency (`None` = deadlock in some scenario), the
/// robustness gap, how many scenario members evaluated the lane, and the
/// lane's merged simulator telemetry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneEval {
    /// Aggregated latency (`None` = infeasible).
    pub latency: Option<u64>,
    /// Worst − best per-scenario latency (`None` on deadlock).
    pub gap: Option<u64>,
    /// Scenario members that evaluated this lane (< `num_scenarios` only
    /// when the early-exit path dropped a deadlocked lane from later
    /// sub-batches).
    pub scen_runs: u32,
    /// Merged telemetry (summed over the scenarios that ran the lane).
    pub run: RunInfo,
}

/// Lane-packing telemetry of one [`ScenarioSim::eval_batch`] call — the
/// engine folds these into [`EngineStats`](crate::dse::EngineStats)'
/// lane counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchTelemetry {
    /// Lane-batched member walks executed (one `eval_batch` per scenario
    /// member with at least one live lane).
    pub walks: u64,
    /// Depth-vector lanes packed across those walks.
    pub lanes_packed: u64,
    /// Lane capacity of those walks (walks × full batch width) — the
    /// occupancy denominator; shortfall vs `lanes_packed` is lanes the
    /// early-exit path dropped after a deadlock.
    pub lane_slots: u64,
}

/// A bank of per-scenario simulation backends evaluated as one unit.
/// `Clone` duplicates every member's scratch (traces and compiled graph
/// tables stay shared), giving each DSE worker its own full bank of
/// retained schedules.
#[derive(Clone)]
pub struct ScenarioSim {
    sims: Vec<Box<dyn SimBackend>>,
    names: Vec<String>,
    weights: Vec<f64>,
    agg: Aggregation,
    /// Merged telemetry of the most recent call (sums over scenarios;
    /// `incremental` when any member replayed incrementally).
    info: RunInfo,
    /// Worst − best per-scenario latency of the most recent call (`None`
    /// on deadlock).
    gap: Option<u64>,
    /// Per-scenario latencies of the most recent call.
    per_lat: Vec<Option<u64>>,
    /// Scratch buffer for per-scenario stats before max-merging.
    scratch: ChannelStats,
    /// Per-scenario deadlock counts observed so far — drives the
    /// [`eval_latency`](Self::eval_latency) early-exit probe order.
    dl_count: Vec<u64>,
    /// Probe-order scratch (scenario indices).
    probe_order: Vec<u32>,
    /// Scenario members actually simulated by the most recent call
    /// (< `num_scenarios` only when the early-exit path stopped at a
    /// deadlock).
    scen_runs: u32,
    /// Lane-packing telemetry of the most recent
    /// [`eval_batch`](Self::eval_batch) call.
    batch_tel: BatchTelemetry,
}

impl ScenarioSim {
    /// Build a bank over a workload with default [`SimOptions`] and the
    /// default ([`FastSim`]) backend.
    pub fn new(workload: &Workload) -> ScenarioSim {
        Self::with_options(workload, SimOptions::default())
    }

    /// Build with explicit [`SimOptions`] (applied to every member).
    pub fn with_options(workload: &Workload, opts: SimOptions) -> ScenarioSim {
        Self::with_backend(workload, opts, BackendKind::Fast)
    }

    /// Build with an explicit simulation backend — the CLI's
    /// `--backend {fast,compiled,batched}` bottoms out here; every
    /// scenario member uses the same backend.
    pub fn with_backend(workload: &Workload, opts: SimOptions, kind: BackendKind) -> ScenarioSim {
        let k = workload.num_scenarios();
        ScenarioSim {
            sims: workload
                .scenarios()
                .iter()
                .map(|s| kind.build(Arc::clone(&s.trace), opts))
                .collect(),
            names: workload.scenarios().iter().map(|s| s.name.clone()).collect(),
            weights: workload.weights(),
            agg: Aggregation::default(),
            info: RunInfo::default(),
            gap: None,
            per_lat: Vec::new(),
            scratch: ChannelStats::new(),
            dl_count: vec![0; k],
            probe_order: Vec::with_capacity(k),
            scen_runs: 0,
            batch_tel: BatchTelemetry::default(),
        }
    }

    /// Single-trace bank (the mechanical port of a bare [`FastSim`]).
    pub fn single(trace: Arc<Trace>) -> ScenarioSim {
        Self::from_fastsim(FastSim::new(trace))
    }

    /// Wrap an existing fast simulator (keeps its options and retained
    /// schedule) as a single-scenario bank.
    pub fn from_fastsim(sim: FastSim) -> ScenarioSim {
        Self::from_backend(Box::new(sim))
    }

    /// Wrap any existing backend instance as a single-scenario bank.
    pub fn from_backend(sim: Box<dyn SimBackend>) -> ScenarioSim {
        ScenarioSim {
            sims: vec![sim],
            names: vec!["default".into()],
            weights: vec![1.0],
            agg: Aggregation::default(),
            info: RunInfo::default(),
            gap: None,
            per_lat: Vec::new(),
            scratch: ChannelStats::new(),
            dl_count: vec![0],
            probe_order: Vec::with_capacity(1),
            scen_runs: 0,
            batch_tel: BatchTelemetry::default(),
        }
    }

    /// Report name of the simulation backend the bank members use.
    pub fn backend_name(&self) -> &'static str {
        self.sims[0].name()
    }

    pub fn num_scenarios(&self) -> usize {
        self.sims.len()
    }

    /// Scenario names, in bank order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Aggregation weights, in bank order.
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// The first scenario's trace (topology reference).
    pub fn primary_trace(&self) -> &Arc<Trace> {
        self.sims[0].trace()
    }

    pub fn aggregation(&self) -> Aggregation {
        self.agg
    }

    /// Choose how per-scenario latencies collapse (worst-case default).
    pub fn set_aggregation(&mut self, agg: Aggregation) {
        self.agg = agg;
    }

    /// Enable/disable schedule retention on every member.
    pub fn set_incremental(&mut self, on: bool) {
        for s in &mut self.sims {
            s.set_incremental(on);
        }
    }

    /// Merged telemetry of the most recent call: op counts are summed
    /// over scenarios, `incremental` is set when any member replayed
    /// incrementally. For single-scenario banks this is exactly the
    /// member's [`FastSim::last_run`].
    pub fn last_run(&self) -> RunInfo {
        self.info
    }

    /// Worst − best per-scenario latency of the most recent call (the
    /// robustness gap; 0 for single-scenario banks, `None` on deadlock).
    pub fn last_gap(&self) -> Option<u64> {
        self.gap
    }

    /// Per-scenario latencies of the most recent call (`None` =
    /// deadlock in that scenario). Complete only after the full-run
    /// paths ([`simulate`](Self::simulate) /
    /// [`simulate_with_stats`](Self::simulate_with_stats)); an
    /// early-exited [`eval_latency`](Self::eval_latency) leaves
    /// unprobed scenarios as `None`.
    pub fn scenario_latencies(&self) -> &[Option<u64>] {
        &self.per_lat
    }

    /// Scenario members actually simulated by the most recent call —
    /// `num_scenarios` on the full paths, possibly fewer when
    /// [`eval_latency`](Self::eval_latency) stopped at the first
    /// deadlocked scenario.
    pub fn last_scenarios_run(&self) -> u32 {
        self.scen_runs
    }

    /// Per-member telemetry of the most recent call, in bank order.
    pub fn scenario_runs(&self) -> Vec<RunInfo> {
        self.sims.iter().map(|s| s.last_run()).collect()
    }

    /// Evaluate one configuration against every scenario.
    pub fn simulate(&mut self, depths: &[u32]) -> SimOutcome {
        if self.sims.len() == 1 {
            let out = self.sims[0].simulate(depths);
            self.finish_single(&out);
            return out;
        }
        self.run_all(depths, None)
    }

    /// Latency-only evaluation. With `early_exit` set (the DSE engine's
    /// pruned fast path), any deadlock makes the configuration
    /// infeasible, so the bank probes scenarios in descending
    /// recent-deadlock-frequency order and **stops at the first
    /// deadlocked scenario** — the failing scenario is usually probed
    /// first, and the remaining members are never replayed. Without
    /// `early_exit` this is exactly [`simulate`](Self::simulate)'s
    /// aggregate latency (full blocked-set union semantics stay on the
    /// `simulate`/stats paths, which diagnostics and the CLI use).
    pub fn eval_latency(&mut self, depths: &[u32], early_exit: bool) -> Option<u64> {
        let k = self.sims.len();
        if k == 1 {
            let out = self.sims[0].simulate(depths);
            self.finish_single(&out);
            return out.latency();
        }
        if !early_exit {
            return self.run_all(depths, None).latency();
        }
        self.probe_order.clear();
        self.probe_order.extend(0..k as u32);
        {
            let dl = &self.dl_count;
            self.probe_order
                .sort_by(|&a, &b| dl[b as usize].cmp(&dl[a as usize]).then(a.cmp(&b)));
        }
        self.info = RunInfo::default();
        self.per_lat.clear();
        self.per_lat.resize(k, None);
        self.scen_runs = 0;
        for &iu in &self.probe_order {
            let i = iu as usize;
            let out = self.sims[i].simulate(depths);
            let r = self.sims[i].last_run();
            self.info.incremental |= r.incremental;
            self.info.dirty_channels += r.dirty_channels;
            self.info.replayed_ops += r.replayed_ops;
            self.info.total_ops += r.total_ops;
            self.scen_runs += 1;
            match out {
                SimOutcome::Done { latency } => self.per_lat[i] = Some(latency),
                SimOutcome::Deadlock { .. } => {
                    self.dl_count[i] += 1;
                    self.gap = None;
                    return None;
                }
            }
        }
        let worst = self.per_lat.iter().flatten().max().copied().unwrap_or(0);
        let best = self.per_lat.iter().flatten().min().copied().unwrap_or(0);
        self.gap = Some(worst - best);
        aggregate_latency(&self.per_lat, &self.weights, self.agg)
    }

    /// Lane-packing telemetry of the most recent
    /// [`eval_batch`](Self::eval_batch) call.
    pub fn last_batch_telemetry(&self) -> BatchTelemetry {
        self.batch_tel
    }

    /// Latency-only evaluation of a whole batch of configurations: for
    /// each scenario member (in bank-index order) the live lanes are
    /// packed into one [`SimBackend::eval_batch`] call, so a
    /// lane-batched backend ([`BatchedSim`](super::BatchedSim)) answers
    /// all of them in a single SoA graph walk. Per lane this computes
    /// exactly what [`eval_latency`](Self::eval_latency) computes for
    /// that configuration — deadlock in any scenario → `None`, else the
    /// weighted/worst aggregate plus the robustness gap. With
    /// `early_exit` set, lanes already deadlocked are dropped from the
    /// remaining scenarios' sub-batches (the lane-parallel analogue of
    /// `eval_latency`'s first-deadlock stop; member order here is fixed
    /// bank order, which — like the probe order — is bookkeeping, never
    /// semantics).
    ///
    /// The bank-level single-call accessors ([`last_run`](Self::last_run),
    /// [`last_gap`](Self::last_gap),
    /// [`scenario_latencies`](Self::scenario_latencies),
    /// [`last_scenarios_run`](Self::last_scenarios_run)) describe
    /// single-configuration calls and are **not** updated by this
    /// method; each lane's [`LaneEval`] carries the per-lane equivalents
    /// instead. Only [`last_batch_telemetry`](Self::last_batch_telemetry)
    /// and the adaptive deadlock counters are refreshed.
    pub fn eval_batch(&mut self, configs: &[Box<[u32]>], early_exit: bool) -> Vec<LaneEval> {
        self.eval_batch_cancellable(configs, early_exit, &|| false)
            .expect("the never-abort closure cannot request an abort")
    }

    /// [`eval_batch`](Self::eval_batch) with a cooperative abort check,
    /// polled once per scenario member *before* its packed walk is
    /// issued. Returns `None` when `abort()` fired — the batch stopped
    /// at a scenario boundary and no per-lane results are available
    /// (partial lanes would be misleading: a lane without its worst
    /// scenario looks feasible/faster than it is). A run whose closure
    /// never fires takes exactly the same code path as
    /// [`eval_batch`](Self::eval_batch), so cancellable and plain calls
    /// are bit-identical when not cancelled.
    ///
    /// The closure keeps this module free of any dependency on the DSE
    /// layer's token type — the engine passes a wall-clock/cancel check,
    /// tests can pass arbitrary predicates.
    pub fn eval_batch_cancellable(
        &mut self,
        configs: &[Box<[u32]>],
        early_exit: bool,
        abort: &dyn Fn() -> bool,
    ) -> Option<Vec<LaneEval>> {
        let nb = configs.len();
        let kk = self.sims.len();
        self.batch_tel = BatchTelemetry::default();
        if nb == 0 {
            return Some(Vec::new());
        }
        // Per-lane accumulators.
        let mut runs = vec![RunInfo::default(); nb];
        let mut scen_runs = vec![0u32; nb];
        let mut dead = vec![false; nb];
        // Flat per-lane per-scenario latencies (lane-major: b * kk + i).
        let mut per = vec![None; nb * kk];
        // Packing scratch: sub-batch configs and their source lanes.
        let mut sub: Vec<Box<[u32]>> = Vec::with_capacity(nb);
        let mut src: Vec<usize> = Vec::with_capacity(nb);
        for i in 0..kk {
            if abort() {
                return None;
            }
            sub.clear();
            src.clear();
            for (b, cfg) in configs.iter().enumerate() {
                if early_exit && dead[b] {
                    continue;
                }
                sub.push(cfg.clone());
                src.push(b);
            }
            if sub.is_empty() {
                break;
            }
            self.batch_tel.walks += 1;
            self.batch_tel.lanes_packed += sub.len() as u64;
            self.batch_tel.lane_slots += nb as u64;
            let outs = self.sims[i].eval_batch(&sub);
            debug_assert_eq!(outs.len(), sub.len());
            for ((out, run), &b) in outs.iter().zip(&src) {
                runs[b].incremental |= run.incremental;
                runs[b].dirty_channels += run.dirty_channels;
                runs[b].replayed_ops += run.replayed_ops;
                runs[b].total_ops += run.total_ops;
                scen_runs[b] += 1;
                match out {
                    SimOutcome::Done { latency } => per[b * kk + i] = Some(*latency),
                    SimOutcome::Deadlock { .. } => {
                        // Adaptive probe counters: one bump per
                        // (lane, scenario) deadlock, same as the
                        // single-call paths.
                        self.dl_count[i] += 1;
                        dead[b] = true;
                    }
                }
            }
        }
        Some(
            (0..nb)
                .map(|b| {
                    let lane = &per[b * kk..b * kk + kk];
                    let (latency, gap) = if dead[b] {
                        (None, None)
                    } else {
                        let worst = lane.iter().flatten().max().copied().unwrap_or(0);
                        let best = lane.iter().flatten().min().copied().unwrap_or(0);
                        (
                            aggregate_latency(lane, &self.weights, self.agg),
                            Some(worst - best),
                        )
                    };
                    LaneEval {
                        latency,
                        gap,
                        scen_runs: scen_runs[b],
                        run: runs[b],
                    }
                })
                .collect(),
        )
    }

    /// Evaluate with max-merged per-channel statistics.
    pub fn simulate_with_stats(&mut self, depths: &[u32]) -> (SimOutcome, ChannelStats) {
        let mut stats = ChannelStats::new();
        let out = self.simulate_with_stats_into(depths, &mut stats);
        (out, stats)
    }

    /// [`simulate_with_stats`](Self::simulate_with_stats) into a
    /// caller-owned buffer.
    pub fn simulate_with_stats_into(
        &mut self,
        depths: &[u32],
        stats: &mut ChannelStats,
    ) -> SimOutcome {
        if self.sims.len() == 1 {
            let out = self.sims[0].simulate_with_stats_into(depths, stats);
            self.finish_single(&out);
            return out;
        }
        self.run_all(depths, Some(stats))
    }

    fn finish_single(&mut self, out: &SimOutcome) {
        self.info = self.sims[0].last_run();
        self.per_lat.clear();
        self.per_lat.push(out.latency());
        self.gap = out.latency().map(|_| 0);
        self.scen_runs = 1;
        if out.is_deadlock() {
            self.dl_count[0] += 1;
        }
    }

    fn run_all(&mut self, depths: &[u32], mut stats: Option<&mut ChannelStats>) -> SimOutcome {
        if let Some(buf) = stats.as_deref_mut() {
            let nch = depths.len();
            buf.max_occupancy.clear();
            buf.max_occupancy.resize(nch, 0);
            buf.write_stall.clear();
            buf.write_stall.resize(nch, 0);
            buf.read_stall.clear();
            buf.read_stall.resize(nch, 0);
        }
        self.per_lat.clear();
        self.info = RunInfo::default();
        self.scen_runs = self.sims.len() as u32;
        let mut blocked: Vec<BlockInfo> = Vec::new();
        for (i, sim) in self.sims.iter_mut().enumerate() {
            let out = match stats.as_deref_mut() {
                Some(buf) => {
                    let o = sim.simulate_with_stats_into(depths, &mut self.scratch);
                    for (d, s) in buf.max_occupancy.iter_mut().zip(&self.scratch.max_occupancy) {
                        *d = (*d).max(*s);
                    }
                    for (d, s) in buf.write_stall.iter_mut().zip(&self.scratch.write_stall) {
                        *d = (*d).max(*s);
                    }
                    for (d, s) in buf.read_stall.iter_mut().zip(&self.scratch.read_stall) {
                        *d = (*d).max(*s);
                    }
                    o
                }
                None => sim.simulate(depths),
            };
            let r = sim.last_run();
            self.info.incremental |= r.incremental;
            self.info.dirty_channels += r.dirty_channels;
            self.info.replayed_ops += r.replayed_ops;
            self.info.total_ops += r.total_ops;
            match &out {
                SimOutcome::Done { latency } => self.per_lat.push(Some(*latency)),
                SimOutcome::Deadlock { blocked: b } => {
                    self.per_lat.push(None);
                    self.dl_count[i] += 1;
                    for info in b {
                        if !blocked.contains(info) {
                            blocked.push(info.clone());
                        }
                    }
                }
            }
        }
        if !blocked.is_empty() {
            self.gap = None;
            return SimOutcome::Deadlock { blocked };
        }
        let worst = self.per_lat.iter().flatten().max().copied().unwrap_or(0);
        let best = self.per_lat.iter().flatten().min().copied().unwrap_or(0);
        self.gap = Some(worst - best);
        let latency = aggregate_latency(&self.per_lat, &self.weights, self.agg)
            .expect("all scenarios feasible");
        SimOutcome::Done { latency }
    }
}

// ---------------------------------------------------------------------------
// Per-scenario pressure profiles (distillation / `fifoadvisor info`)
// ---------------------------------------------------------------------------

/// The dominance-relevant fingerprint of one workload scenario: how hard
/// it presses on each channel, and which channels it can deadlock. Built
/// by [`scenario_profiles`]; consumed by the scenario-bank distillation
/// in [`crate::dse::advhunt`] and the `fifoadvisor info` per-scenario
/// table.
#[derive(Debug, Clone)]
pub struct ScenarioProfile {
    /// Scenario name (bank order).
    pub name: String,
    /// The kernel arguments this scenario's trace was collected under.
    pub args: Vec<i64>,
    /// Per-channel peak occupancy of this scenario at the *merged*
    /// Baseline-Max (deadlock-free on every scenario by construction,
    /// so every peak is observable).
    pub peak_occ: Vec<u32>,
    /// Per-channel analytic deadlock floors of this scenario alone
    /// ([`DepthBounds::for_trace`](crate::opt::bounds::DepthBounds)) —
    /// its contribution to the workload's merged floor.
    pub floors: Vec<u32>,
    /// This scenario's latency at the merged Baseline-Max.
    pub base_latency: u64,
    /// Channels this scenario blocks on at Baseline-Min (depth 2
    /// everywhere) — its deadlock-relevant blocked set (empty when the
    /// scenario is feasible even at minimum depths). Sorted, deduped.
    pub blocked: Vec<usize>,
}

impl ScenarioProfile {
    /// Componentwise dominance: `self` is redundant next to `other` when
    /// every per-channel occupancy peak and deadlock floor is covered,
    /// its Baseline-Min blocked set is a subset, and it is no slower at
    /// Baseline-Max. A dominated scenario can never be the unique
    /// witness of a deadlock floor or the worst-case latency *under this
    /// heuristic's observations* — the distillation loop still
    /// re-verifies against the full bank, so dominance only has to be a
    /// good guess, never a proof.
    pub fn dominated_by(&self, other: &ScenarioProfile) -> bool {
        self.peak_occ
            .iter()
            .zip(&other.peak_occ)
            .all(|(a, b)| a <= b)
            && self.floors.iter().zip(&other.floors).all(|(a, b)| a <= b)
            && self.blocked.iter().all(|c| other.blocked.contains(c))
            && self.base_latency <= other.base_latency
    }
}

/// Profile every scenario of a workload: one stats run per scenario at
/// the merged Baseline-Max (peaks + latency), one run at Baseline-Min
/// (blocked set), and the per-trace analytic depth bounds. Cost is
/// `2 × num_scenarios` simulations plus one bounds pass per scenario —
/// cheap next to a DSE run, and independent of any engine state.
pub fn scenario_profiles(workload: &Workload) -> Vec<ScenarioProfile> {
    let bmax = workload.baseline_max();
    let bmin = workload.baseline_min();
    workload
        .scenarios()
        .iter()
        .map(|s| {
            let mut sim = FastSim::new(Arc::clone(&s.trace));
            let (out, stats) = sim.simulate_with_stats(&bmax);
            let base_latency = out
                .latency()
                .expect("merged Baseline-Max is deadlock-free on every scenario");
            let mut blocked: Vec<usize> = match sim.simulate(&bmin) {
                SimOutcome::Done { .. } => Vec::new(),
                SimOutcome::Deadlock { blocked } => blocked.iter().map(|b| b.channel).collect(),
            };
            blocked.sort_unstable();
            blocked.dedup();
            let floors = crate::opt::bounds::DepthBounds::for_trace(&s.trace).floors;
            ScenarioProfile {
                name: s.name.clone(),
                args: s.trace.args.clone(),
                peak_occ: stats.max_occupancy,
                floors,
                base_latency,
                blocked,
            }
        })
        .collect()
}

/// Greedy keep/drop partition over [`scenario_profiles`]: scenario `i`
/// is dropped when some *kept* sibling dominates it (ties keep the
/// earlier index, so the result is deterministic and at least one
/// scenario always survives). Returns `(kept, dropped)` index lists in
/// bank order plus, for each dropped scenario, the kept index that
/// dominated it.
pub fn distill_partition(profiles: &[ScenarioProfile]) -> (Vec<usize>, Vec<(usize, usize)>) {
    let mut kept: Vec<usize> = Vec::new();
    let mut dropped: Vec<(usize, usize)> = Vec::new();
    for i in 0..profiles.len() {
        // A scenario is dominated by an earlier keeper, or by a *later*
        // scenario that itself is not dominated by i (strictly greater
        // somewhere) — handle the simple transitive-safe rule: compare
        // against every other scenario, preferring earlier dominators,
        // but never drop i for a later twin that i also dominates
        // (identical profiles: keep the earlier).
        let mut dominator = None;
        for j in 0..profiles.len() {
            if i == j {
                continue;
            }
            if profiles[i].dominated_by(&profiles[j]) {
                let tie = profiles[j].dominated_by(&profiles[i]);
                if tie && j > i {
                    continue; // identical twins: earlier index wins
                }
                dominator = Some(j);
                break;
            }
        }
        match dominator {
            Some(j) => dropped.push((i, j)),
            None => kept.push(i),
        }
    }
    // Chains of identical profiles could in principle drop everything's
    // head — guard the invariant that something survives.
    if kept.is_empty() {
        let (i, _) = dropped.remove(0);
        kept.push(i);
    }
    // A dropped scenario whose recorded dominator was itself dropped is
    // still covered transitively (dominance over these componentwise
    // orders is transitive), but re-point the report at a kept scenario
    // for readability.
    let final_dominator: Vec<(usize, usize)> = dropped
        .iter()
        .map(|&(i, mut j)| {
            let mut hops = 0;
            while !kept.contains(&j) && hops < profiles.len() {
                match dropped.iter().find(|&&(d, _)| d == j) {
                    Some(&(_, next)) => j = next,
                    None => break,
                }
                hops += 1;
            }
            (i, j)
        })
        .collect();
    (kept, final_dominator)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;

    fn fig2_workload(ns: &[i64]) -> Workload {
        let bd = bench_suite::build("fig2");
        let named: Vec<(String, Vec<i64>)> =
            ns.iter().map(|&n| (format!("n{n}"), vec![n])).collect();
        Workload::from_design(&bd.design, &named).unwrap()
    }

    #[test]
    fn worst_case_latency_and_any_scenario_deadlock() {
        let w = fig2_workload(&[8, 16]);
        let mut bank = ScenarioSim::new(&w);
        // Ample depths: feasible everywhere; latency = the slowest (n=16)
        // scenario's.
        let out = bank.simulate(&[16, 2]);
        let per: Vec<Option<u64>> = w
            .scenarios()
            .iter()
            .map(|s| {
                FastSim::new(Arc::clone(&s.trace))
                    .simulate(&[16, 2])
                    .latency()
            })
            .collect();
        assert_eq!(out.latency(), per.iter().flatten().max().copied());
        assert_eq!(bank.scenario_latencies(), per.as_slice());
        assert_eq!(
            bank.last_gap(),
            Some(per.iter().flatten().max().unwrap() - per.iter().flatten().min().unwrap())
        );
        // x depth 7 is feasible for n=8 but deadlocks n=16 → the workload
        // is infeasible.
        let out = bank.simulate(&[7, 2]);
        assert!(out.is_deadlock());
        assert!(bank.scenario_latencies()[0].is_some());
        assert_eq!(bank.scenario_latencies()[1], None);
        assert_eq!(bank.last_gap(), None);
    }

    #[test]
    fn weighted_aggregation_averages() {
        let w = fig2_workload(&[8, 16]);
        let mut bank = ScenarioSim::new(&w);
        bank.set_aggregation(Aggregation::Weighted);
        let out = bank.simulate(&[16, 2]);
        let per: Vec<u64> = bank
            .scenario_latencies()
            .iter()
            .map(|l| l.unwrap())
            .collect();
        let mean = ((per[0] + per[1]) as f64 / 2.0).round() as u64;
        assert_eq!(out.latency(), Some(mean));
    }

    #[test]
    fn per_scenario_delta_replay_engages() {
        let w = fig2_workload(&[8, 16, 12]);
        let mut bank = ScenarioSim::new(&w);
        bank.simulate(&[16, 16]);
        assert!(!bank.last_run().incremental, "first run is cold");
        // A 1-channel mutation: every member should replay its own delta.
        bank.simulate(&[16, 8]);
        let runs = bank.scenario_runs();
        assert_eq!(runs.len(), 3);
        assert!(
            runs.iter().all(|r| r.incremental),
            "every scenario member should delta-replay: {runs:?}"
        );
        assert!(bank.last_run().incremental);
        assert_eq!(
            bank.last_run().total_ops,
            runs.iter().map(|r| r.total_ops).sum::<u64>()
        );
    }

    #[test]
    fn stats_are_max_merged() {
        let w = fig2_workload(&[8, 16]);
        let mut bank = ScenarioSim::new(&w);
        let (_, merged) = bank.simulate_with_stats(&[16, 2]);
        let per: Vec<ChannelStats> = w
            .scenarios()
            .iter()
            .map(|s| {
                FastSim::new(Arc::clone(&s.trace))
                    .simulate_with_stats(&[16, 2])
                    .1
            })
            .collect();
        for ch in 0..w.num_fifos() {
            assert_eq!(
                merged.max_occupancy[ch],
                per.iter().map(|s| s.max_occupancy[ch]).max().unwrap()
            );
            assert_eq!(
                merged.write_stall[ch],
                per.iter().map(|s| s.write_stall[ch]).max().unwrap()
            );
            assert_eq!(
                merged.read_stall[ch],
                per.iter().map(|s| s.read_stall[ch]).max().unwrap()
            );
        }
    }

    #[test]
    fn eval_latency_matches_simulate_and_early_exits() {
        let w = fig2_workload(&[8, 16, 12]);
        let mut bank = ScenarioSim::new(&w);
        let mut full = ScenarioSim::new(&w);
        // Verdicts and latencies agree with the full path on feasible,
        // deadlocked, and boundary configurations, early exit on or off.
        for cfg in [[16u32, 2], [7, 2], [15, 2], [2, 2], [11, 3]] {
            let want = full.simulate(&cfg).latency();
            assert_eq!(bank.eval_latency(&cfg, true), want, "early {cfg:?}");
            assert_eq!(bank.eval_latency(&cfg, false), want, "full {cfg:?}");
        }
        // Feasible evaluations run (and count) every scenario.
        assert_eq!(bank.eval_latency(&[16, 2], true), full.simulate(&[16, 2]).latency());
        assert_eq!(bank.last_scenarios_run(), 3);
        assert_eq!(bank.last_gap(), full.last_gap());
        // A deadlock stops the probe sequence; the adaptive order puts
        // the scenario that just failed first, so an immediate re-probe
        // of a deadlocking configuration touches exactly one member.
        assert_eq!(bank.eval_latency(&[7, 2], true), None);
        let first = bank.last_scenarios_run();
        assert!(first >= 1 && first < 3, "must stop early: {first}");
        assert_eq!(bank.eval_latency(&[7, 3], true), None);
        assert_eq!(
            bank.last_scenarios_run(),
            1,
            "failing scenario should be probed first after a deadlock"
        );
        assert_eq!(bank.last_gap(), None);
    }

    #[test]
    fn eval_latency_single_bank_is_exact() {
        let bd = bench_suite::build("fig2");
        let t = Arc::new(
            crate::trace::collect_trace(&bd.design, &bd.args).unwrap(),
        );
        let mut bank = ScenarioSim::single(t.clone());
        let mut fast = FastSim::new(t.clone());
        for cfg in [[16u32, 2], [2, 2], [16, 16]] {
            assert_eq!(bank.eval_latency(&cfg, true), fast.simulate(&cfg).latency());
            assert_eq!(bank.last_run(), fast.last_run());
            assert_eq!(bank.last_scenarios_run(), 1);
        }
    }

    #[test]
    fn compiled_backend_bank_matches_fast_backend_bank() {
        let w = fig2_workload(&[8, 16, 12]);
        let mut fast_bank = ScenarioSim::new(&w);
        let mut comp_bank =
            ScenarioSim::with_backend(&w, SimOptions::default(), BackendKind::Compiled);
        assert_eq!(fast_bank.backend_name(), "fast");
        assert_eq!(comp_bank.backend_name(), "compiled");
        for cfg in [[16u32, 2], [7, 2], [2, 2], [15, 3], [16, 16]] {
            assert_eq!(
                fast_bank.simulate(&cfg),
                comp_bank.simulate(&cfg),
                "cfg {cfg:?}"
            );
            assert_eq!(
                fast_bank.scenario_latencies(),
                comp_bank.scenario_latencies(),
                "cfg {cfg:?}"
            );
            let (fo, fs) = fast_bank.simulate_with_stats(&cfg);
            let (co, cs) = comp_bank.simulate_with_stats(&cfg);
            assert_eq!(fo, co, "cfg {cfg:?}");
            assert_eq!(fs.max_occupancy, cs.max_occupancy, "cfg {cfg:?}");
            assert_eq!(fs.write_stall, cs.write_stall, "cfg {cfg:?}");
            assert_eq!(fs.read_stall, cs.read_stall, "cfg {cfg:?}");
        }
    }

    /// Regression (probe-reordering bookkeeping): the early-exit probe
    /// order is a pure function of the per-scenario deadlock counts with
    /// a pinned tie-break — descending count, then ascending scenario
    /// index — so identical call histories always probe identically, and
    /// probe order can never change a verdict or latency.
    #[test]
    fn early_exit_probe_order_is_deterministic_under_ties() {
        // fig2 scenarios n = [8, 16, 12]: x deadlocks scenario i iff
        // depth(x) < n_i - 1 (thresholds 7, 15, 11).
        let w = fig2_workload(&[8, 16, 12]);
        let mut bank = ScenarioSim::new(&w);
        let mut twin = ScenarioSim::new(&w);
        let mut full = ScenarioSim::new(&w);

        // All counts tied at 0: probes run in ascending index order, so a
        // config that deadlocks only scenario 1 (x = 11: feasible for
        // n=8 and n=12, deadlocks n=16) probes 0 then 1 — exactly 2 runs.
        assert_eq!(bank.eval_latency(&[11, 2], true), None);
        assert_eq!(bank.last_scenarios_run(), 2, "tie must break by index");

        // Scenario 1 now leads the counts: it is probed first.
        assert_eq!(bank.eval_latency(&[11, 3], true), None);
        assert_eq!(bank.last_scenarios_run(), 1);

        // Scenarios 0 and 2 still tie at 0: a config deadlocking both
        // (x = 2) probes 1 first (count 2), and the tied remainder in
        // index order — but it deadlocks at the first probe regardless.
        assert_eq!(bank.eval_latency(&[2, 2], true), None);
        assert_eq!(bank.last_scenarios_run(), 1);

        // Probe order is bookkeeping, never semantics: however the two
        // banks' histories (and therefore probe orders) differ, both
        // always agree with the full no-early-exit path on verdict and
        // latency. `twin` additionally replays `bank`'s exact first three
        // calls afterwards and must land on identical scenario-run counts.
        for cfg in [[11u32, 2], [11, 3], [2, 2], [16, 2], [10, 2], [16, 16]] {
            let a = twin.eval_latency(&cfg, true);
            let b = bank.eval_latency(&cfg, true);
            let want = full.simulate(&cfg).latency();
            assert_eq!(b, want, "cfg {cfg:?}: early-exit verdict diverged");
            assert_eq!(a, want, "cfg {cfg:?}: twin verdict diverged");
        }
        let mut replay = ScenarioSim::new(&w);
        for (cfg, runs) in [([11u32, 2], 2u32), ([11, 3], 1), ([2, 2], 1)] {
            assert_eq!(replay.eval_latency(&cfg, true), None);
            assert_eq!(
                replay.last_scenarios_run(),
                runs,
                "cfg {cfg:?}: identical history must probe identically"
            );
        }

        // Deadlock counts are bookkeeping, not semantics: a fresh bank
        // (all ties, index-order probes) reaches the same verdicts.
        let mut fresh = ScenarioSim::new(&w);
        for cfg in [[11u32, 2], [2, 2], [16, 2], [14, 2]] {
            assert_eq!(
                fresh.eval_latency(&cfg, true),
                full.simulate(&cfg).latency(),
                "cfg {cfg:?}"
            );
        }
    }

    /// The lane-batched bank path computes, per lane, exactly what the
    /// single-configuration path computes — latency, gap, and scenario
    /// run counts — with early exit on or off, for every backend kind.
    #[test]
    fn eval_batch_lanes_match_per_config_eval() {
        let w = fig2_workload(&[8, 16, 12]);
        let cfgs: Vec<Box<[u32]>> = [
            [16u32, 2],
            [7, 2],   // deadlocks n=16 only
            [15, 2],  // boundary: feasible everywhere
            [2, 2],   // deadlocks everywhere
            [16, 2],  // duplicate of lane 0
            [11, 3],  // deadlocks n=16 only
            [16, 16], // ample
        ]
        .iter()
        .map(|c| c.to_vec().into_boxed_slice())
        .collect();
        for kind in [BackendKind::Fast, BackendKind::Compiled, BackendKind::Batched] {
            for early in [false, true] {
                let mut bank = ScenarioSim::with_backend(&w, SimOptions::default(), kind);
                let mut solo = ScenarioSim::new(&w);
                let lanes = bank.eval_batch(&cfgs, early);
                assert_eq!(lanes.len(), cfgs.len());
                for (le, cfg) in lanes.iter().zip(&cfgs) {
                    let want = solo.simulate(cfg).latency();
                    assert_eq!(le.latency, want, "{kind:?} early={early} cfg {cfg:?}");
                    assert_eq!(le.gap, solo.last_gap(), "{kind:?} early={early} cfg {cfg:?}");
                    if !early {
                        assert_eq!(le.scen_runs, 3);
                        assert_eq!(le.run.total_ops, solo.last_run().total_ops);
                    } else if want.is_some() {
                        assert_eq!(le.scen_runs, 3, "feasible lanes run every scenario");
                    } else {
                        assert!(le.scen_runs >= 1 && le.scen_runs <= 3);
                    }
                }
                // Telemetry: every scenario walks once without early exit
                // (full lane occupancy); with it, dead lanes drop out of
                // later walks.
                let tel = bank.last_batch_telemetry();
                assert_eq!(tel.walks, 3);
                assert_eq!(tel.lane_slots, 3 * cfgs.len() as u64);
                if early {
                    assert!(tel.lanes_packed < tel.lane_slots, "{tel:?}");
                } else {
                    assert_eq!(tel.lanes_packed, tel.lane_slots);
                }
            }
        }
        // Empty batches are a no-op.
        let mut bank = ScenarioSim::new(&w);
        assert!(bank.eval_batch(&[], true).is_empty());
        assert_eq!(bank.last_batch_telemetry(), BatchTelemetry::default());
    }

    #[test]
    fn profiles_capture_pressure_and_dominance() {
        let w = fig2_workload(&[8, 16, 12]);
        let profs = scenario_profiles(&w);
        assert_eq!(profs.len(), 3);
        // fig2: x write count = n, so the n=16 scenario presses hardest
        // on x, has the largest floor (n − 1), and the longest run.
        assert!(profs[1].peak_occ[0] > profs[0].peak_occ[0]);
        assert_eq!(profs[1].floors[0], 15);
        assert_eq!(profs[0].floors[0], 7);
        assert!(profs[1].base_latency >= profs[0].base_latency);
        // Every fig2 scenario deadlocks at Baseline-Min on channel x.
        for p in &profs {
            assert!(p.blocked.contains(&0), "{}: {:?}", p.name, p.blocked);
        }
        // n=8 and n=12 are dominated by n=16; n=16 is not dominated.
        assert!(profs[0].dominated_by(&profs[1]));
        assert!(profs[2].dominated_by(&profs[1]));
        assert!(!profs[1].dominated_by(&profs[0]));
        let (kept, dropped) = distill_partition(&profs);
        assert_eq!(kept, vec![1]);
        assert_eq!(dropped, vec![(0, 1), (2, 1)]);
        // Identical twins keep the earlier index.
        let twins = vec![profs[0].clone(), profs[0].clone()];
        let (kept, dropped) = distill_partition(&twins);
        assert_eq!(kept, vec![0]);
        assert_eq!(dropped, vec![(1, 0)]);
    }

    #[test]
    fn blocked_union_is_deduplicated() {
        let w = fig2_workload(&[8, 16]);
        let mut bank = ScenarioSim::new(&w);
        // Depth 2 deadlocks both scenarios at the same (process, channel)
        // points; the union must not repeat them.
        let out = bank.simulate(&[2, 2]);
        match out {
            SimOutcome::Deadlock { blocked } => {
                for (i, b) in blocked.iter().enumerate() {
                    assert!(!blocked[..i].contains(b), "duplicate block info");
                }
            }
            other => panic!("expected deadlock, got {other:?}"),
        }
    }
}
