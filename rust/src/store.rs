//! The disk-backed cross-run cache — persistent memo/oracle snapshots
//! keyed by what they are sound for.
//!
//! One optimize run teaches the engine two reusable things: the memo
//! cache (`depths → (latency, bram)`) and the dominance oracle's
//! feasibility antichains. Both are functions of the workload's
//! recorded traces alone, so a *later* run over the same traces can
//! import them and answer every repeated proposal without simulating —
//! the replay guarantee the serve mode and the `--cache-dir` CLI flag
//! build on.
//!
//! # Keying (what makes reuse sound)
//!
//! A snapshot is stored under `fnv1a` of:
//!
//! - the store format version,
//! - the design name,
//! - the simulation backend name and the prune/bounds flags,
//! - the workload's **full compact JSON** — which embeds every
//!   scenario's trace ops verbatim, so the key pins the exact traces,
//!   not just the design/argument names.
//!
//! Memo entries are exact simulation results and deadlock is monotone
//! in depths, so under an identical-trace key both structures transfer
//! verbatim (see [`FeasibilityOracle::entries`] for the oracle's
//! argument). On top of the key, every snapshot embeds the freshly
//! recomputed [`DepthBounds::fingerprint`] and each memo entry's BRAM
//! total is re-derived on import — a snapshot that parses but
//! disagrees with the present analysis is rejected wholesale.
//!
//! # Durability & corruption
//!
//! Snapshots are written through [`atomic_write`] (temp file + fsync +
//! rename + parent-directory fsync), carry a format version and an
//! FNV-1a payload checksum, and are validated structurally on load.
//! *Any* load failure — missing file, truncation, bit garble, wrong
//! version, checksum or fingerprint mismatch — degrades to a cold
//! start with a stderr warning; it can never panic or change results.
//!
//! # Eviction
//!
//! A sidecar `index.json` tracks per-snapshot byte sizes and a logical
//! LRU clock. When the store exceeds its size budget, least-recently-
//! used snapshots are deleted (never the one just written). The index
//! is best-effort: concurrent writers may lose a `last_used` bump, but
//! snapshot files themselves are only ever replaced atomically, so
//! readers always see a complete, checksummed snapshot or none.
//!
//! [`FeasibilityOracle::entries`]: crate::opt::dominance::FeasibilityOracle::entries
//! [`DepthBounds::fingerprint`]: crate::opt::bounds::DepthBounds::fingerprint

use crate::bram;
use crate::dse::{EvalEngine, MemoEntry, OracleEntry};
use crate::trace::workload::Workload;
use crate::util::json::Json;
use crate::util::{atomic_write, fnv1a};
use std::collections::BTreeMap;
use std::fs;
use std::path::PathBuf;

/// Bumped whenever the snapshot layout changes; part of the cache key,
/// so old-format files are simply never looked up (and age out by LRU).
pub const FORMAT_VERSION: u64 = 1;

fn hex16(h: u64) -> String {
    format!("{h:016x}")
}

// ---------------------------------------------------------------------------
// Snapshot: what one engine's reusable knowledge looks like at rest
// ---------------------------------------------------------------------------

/// An engine's persistable knowledge: sorted memo entries, the oracle's
/// antichains, and the identity/regime fields that gate reuse.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub design: String,
    /// Simulation backend name (`fast`/`compiled`/`batched`). Backends
    /// are result-identical, but keeping regimes separate keeps each
    /// snapshot's provenance auditable.
    pub backend: String,
    pub prune: bool,
    pub bounds: bool,
    /// Channel count — a cheap shape check before anything is imported.
    pub channels: usize,
    /// [`DepthBounds::fingerprint`] of the producing engine; must match
    /// the freshly recomputed bounds of the consuming engine.
    ///
    /// [`DepthBounds::fingerprint`]: crate::opt::bounds::DepthBounds::fingerprint
    pub bounds_fp: u64,
    /// `(depths, latency, bram)`, sorted by depths.
    pub memo: Vec<MemoEntry>,
    /// The oracle's `(depths, latency)` outcomes (infeasible side
    /// first), replayed through `note` on import.
    pub oracle: Vec<OracleEntry>,
}

impl Snapshot {
    /// Capture the engine's current memo + oracle state.
    pub fn capture(design: &str, engine: &EvalEngine) -> Snapshot {
        Snapshot {
            design: design.to_string(),
            backend: engine.sim_backend().name().to_string(),
            prune: engine.prune(),
            bounds: engine.bounds(),
            channels: engine.widths.len(),
            bounds_fp: engine.depth_bounds().fingerprint(),
            memo: engine.memo_entries(),
            oracle: engine.oracle().entries(),
        }
    }

    /// Import into a freshly built engine, after validating that the
    /// snapshot belongs to this engine's exact regime: channel count,
    /// backend, prune/bounds flags, the recomputed bounds fingerprint,
    /// and every memo entry's BRAM total re-derived from the engine's
    /// own widths (integrity beyond the file checksum). Returns the
    /// number of memo entries imported; any mismatch rejects the whole
    /// snapshot without touching the engine.
    pub fn apply(&self, engine: &mut EvalEngine) -> Result<usize, String> {
        if self.channels != engine.widths.len() {
            return Err(format!(
                "channel count mismatch: snapshot {}, engine {}",
                self.channels,
                engine.widths.len()
            ));
        }
        if self.backend != engine.sim_backend().name() {
            return Err(format!(
                "backend mismatch: snapshot {}, engine {}",
                self.backend,
                engine.sim_backend().name()
            ));
        }
        if self.prune != engine.prune() || self.bounds != engine.bounds() {
            return Err("prune/bounds regime mismatch".to_string());
        }
        let fresh = engine.depth_bounds().fingerprint();
        if self.bounds_fp != fresh {
            return Err(format!(
                "bounds fingerprint mismatch: snapshot {:016x}, recomputed {fresh:016x}",
                self.bounds_fp
            ));
        }
        for (depths, _, bram) in &self.memo {
            if depths.len() != self.channels {
                return Err("memo entry with wrong channel count".to_string());
            }
            let want = bram::bram_total(depths, &engine.widths);
            if *bram != want {
                return Err(format!(
                    "memo entry {depths:?}: recorded bram {bram}, recomputed {want}"
                ));
            }
        }
        for (depths, _) in &self.oracle {
            if depths.len() != self.channels {
                return Err("oracle entry with wrong channel count".to_string());
            }
        }
        let n = engine.import_memo(&self.memo);
        engine.import_oracle(&self.oracle);
        Ok(n)
    }

    /// The snapshot's JSON payload (deterministic: BTreeMap keys, memo
    /// pre-sorted by the exporter).
    pub fn to_json(&self) -> Json {
        let lat = |l: &Option<u64>| match l {
            Some(v) => Json::Num(*v as f64),
            None => Json::Null,
        };
        let memo = Json::Arr(
            self.memo
                .iter()
                .map(|(d, l, b)| Json::Arr(vec![Json::nums(d), lat(l), Json::Num(*b as f64)]))
                .collect(),
        );
        let oracle = Json::Arr(
            self.oracle
                .iter()
                .map(|(d, l)| Json::Arr(vec![Json::nums(d), lat(l)]))
                .collect(),
        );
        Json::obj(vec![
            ("design", Json::Str(self.design.clone())),
            ("backend", Json::Str(self.backend.clone())),
            ("prune", Json::Bool(self.prune)),
            ("bounds", Json::Bool(self.bounds)),
            ("channels", Json::Num(self.channels as f64)),
            ("bounds_fp", Json::Str(hex16(self.bounds_fp))),
            ("memo", memo),
            ("oracle", oracle),
        ])
    }

    /// Parse a payload object, with full shape validation.
    pub fn from_json(v: &Json) -> Result<Snapshot, String> {
        fn depths_of(v: &Json) -> Result<Vec<u32>, String> {
            v.as_arr()
                .ok_or("depths not an array")?
                .iter()
                .map(|d| {
                    d.as_u64()
                        .and_then(|u| u32::try_from(u).ok())
                        .ok_or_else(|| "depth out of range".to_string())
                })
                .collect()
        }
        fn lat_of(v: &Json) -> Result<Option<u64>, String> {
            match v {
                Json::Null => Ok(None),
                other => other.as_u64().map(Some).ok_or_else(|| "bad latency".to_string()),
            }
        }
        let str_field = |k: &str| -> Result<String, String> {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("missing field '{k}'"))
        };
        let bool_field = |k: &str| -> Result<bool, String> {
            v.get(k)
                .and_then(Json::as_bool)
                .ok_or_else(|| format!("missing field '{k}'"))
        };
        let channels = v
            .get("channels")
            .and_then(Json::as_u64)
            .ok_or("missing field 'channels'")? as usize;
        let bounds_fp_hex = str_field("bounds_fp")?;
        let bounds_fp =
            u64::from_str_radix(&bounds_fp_hex, 16).map_err(|_| "bad bounds_fp".to_string())?;
        let mut memo = Vec::new();
        for e in v
            .get("memo")
            .and_then(Json::as_arr)
            .ok_or("missing field 'memo'")?
        {
            let t = e.as_arr().filter(|t| t.len() == 3).ok_or("bad memo entry")?;
            let bram = t[2]
                .as_u64()
                .and_then(|u| u32::try_from(u).ok())
                .ok_or("bad memo bram")?;
            memo.push((depths_of(&t[0])?, lat_of(&t[1])?, bram));
        }
        let mut oracle = Vec::new();
        for e in v
            .get("oracle")
            .and_then(Json::as_arr)
            .ok_or("missing field 'oracle'")?
        {
            let t = e.as_arr().filter(|t| t.len() == 2).ok_or("bad oracle entry")?;
            oracle.push((depths_of(&t[0])?, lat_of(&t[1])?));
        }
        Ok(Snapshot {
            design: str_field("design")?,
            backend: str_field("backend")?,
            prune: bool_field("prune")?,
            bounds: bool_field("bounds")?,
            channels,
            bounds_fp,
            memo,
            oracle,
        })
    }
}

// ---------------------------------------------------------------------------
// Store: the on-disk cache directory
// ---------------------------------------------------------------------------

/// A cache directory of checksummed snapshots plus a best-effort LRU
/// index. Cheap to construct (no I/O until `load`/`save`).
pub struct Store {
    dir: PathBuf,
    /// Size budget in bytes; 0 = unlimited.
    max_bytes: u64,
}

impl Store {
    /// `max_mb = 0` disables eviction.
    pub fn new(dir: &str, max_mb: u64) -> Store {
        Store {
            dir: PathBuf::from(dir),
            max_bytes: max_mb.saturating_mul(1024 * 1024),
        }
    }

    /// The cache directory.
    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }

    /// The 16-hex cache key for one (design, workload, regime). Hashes
    /// the workload's full compact JSON — traces included — so two
    /// workloads agree on a key only if their recorded ops are
    /// byte-identical.
    pub fn key(
        design: &str,
        workload: &Workload,
        backend: &str,
        prune: bool,
        bounds: bool,
    ) -> String {
        let mut s = format!("v{FORMAT_VERSION};{design};{backend};prune={prune};bounds={bounds};");
        s.push_str(&workload.to_json().to_string_compact());
        hex16(fnv1a(s.as_bytes()))
    }

    fn snapshot_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.json"))
    }

    fn index_path(&self) -> PathBuf {
        self.dir.join("index.json")
    }

    /// Load and validate the snapshot under `key`. A missing file is a
    /// silent `None` (the expected cold-start case); any parse,
    /// checksum or shape failure warns on stderr and returns `None` —
    /// corruption degrades to a cold start, never a panic or a wrong
    /// answer (regime/BRAM validation happens later in
    /// [`Snapshot::apply`]).
    pub fn load(&self, key: &str) -> Option<Snapshot> {
        let path = self.snapshot_path(key);
        let text = fs::read_to_string(&path).ok()?;
        match Self::parse_snapshot(&text) {
            Ok(snap) => {
                self.touch(key);
                Some(snap)
            }
            Err(e) => {
                eprintln!(
                    "warning: store: ignoring corrupt snapshot {} ({e}); cold start",
                    path.display()
                );
                None
            }
        }
    }

    /// Parse + verify one snapshot file's text (exposed for fuzzing).
    pub fn parse_snapshot(text: &str) -> Result<Snapshot, String> {
        let v = Json::parse(text).map_err(|e| format!("json: {e:?}"))?;
        let version = v
            .get("version")
            .and_then(Json::as_u64)
            .ok_or("missing version")?;
        if version != FORMAT_VERSION {
            return Err(format!("unsupported format version {version}"));
        }
        let payload = v.get("payload").ok_or("missing payload")?;
        let want = v
            .get("checksum")
            .and_then(Json::as_str)
            .ok_or("missing checksum")?;
        let got = hex16(fnv1a(payload.to_string_compact().as_bytes()));
        if want != got {
            return Err(format!("checksum mismatch: recorded {want}, computed {got}"));
        }
        Snapshot::from_json(payload)
    }

    /// Persist a snapshot under `key` (atomic write + fsyncs), update
    /// the LRU index, and evict least-recently-used snapshots beyond
    /// the size budget.
    pub fn save(&self, key: &str, snap: &Snapshot) -> std::io::Result<()> {
        let payload = snap.to_json();
        let checksum = hex16(fnv1a(payload.to_string_compact().as_bytes()));
        let file = Json::obj(vec![
            ("version", Json::Num(FORMAT_VERSION as f64)),
            ("checksum", Json::Str(checksum)),
            ("payload", payload),
        ]);
        let text = file.to_string_compact();
        let path = self.snapshot_path(key);
        atomic_write(&path.to_string_lossy(), &text)?;
        self.update_index(key, text.len() as u64);
        Ok(())
    }

    // -- LRU index (best-effort; snapshot files stay atomic regardless) --

    /// `(clock, key → (bytes, last_used))`; any unreadable index is an
    /// empty one.
    fn read_index(&self) -> (u64, BTreeMap<String, (u64, u64)>) {
        let mut out = BTreeMap::new();
        let text = match fs::read_to_string(self.index_path()) {
            Ok(t) => t,
            Err(_) => return (0, out),
        };
        let v = match Json::parse(&text) {
            Ok(v) => v,
            Err(_) => return (0, out),
        };
        let clock = v.get("clock").and_then(Json::as_u64).unwrap_or(0);
        if let Some(Json::Obj(entries)) = v.get("entries") {
            for (k, e) in entries {
                let bytes = e.get("bytes").and_then(Json::as_u64).unwrap_or(0);
                let used = e.get("last_used").and_then(Json::as_u64).unwrap_or(0);
                out.insert(k.clone(), (bytes, used));
            }
        }
        (clock, out)
    }

    fn write_index(&self, clock: u64, entries: &BTreeMap<String, (u64, u64)>) {
        let obj = Json::Obj(
            entries
                .iter()
                .map(|(k, (bytes, used))| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("bytes", Json::Num(*bytes as f64)),
                            ("last_used", Json::Num(*used as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        let v = Json::obj(vec![
            ("version", Json::Num(FORMAT_VERSION as f64)),
            ("clock", Json::Num(clock as f64)),
            ("entries", obj),
        ]);
        // Index loss is recoverable (it only orders eviction), so write
        // failures are tolerated.
        let _ = atomic_write(&self.index_path().to_string_lossy(), &v.to_string_compact());
    }

    fn update_index(&self, key: &str, bytes: u64) {
        let (mut clock, mut entries) = self.read_index();
        clock += 1;
        entries.insert(key.to_string(), (bytes, clock));
        if self.max_bytes > 0 {
            let mut total: u64 = entries.values().map(|(b, _)| *b).sum();
            while total > self.max_bytes {
                // Evict the least-recently-used snapshot, but never the
                // one just written.
                let victim = entries
                    .iter()
                    .filter(|(k, _)| k.as_str() != key)
                    .min_by_key(|(_, (_, used))| *used)
                    .map(|(k, _)| k.clone());
                let Some(victim) = victim else { break };
                let (b, _) = entries.remove(&victim).unwrap_or((0, 0));
                total -= b.min(total);
                let _ = fs::remove_file(self.snapshot_path(&victim));
            }
        }
        self.write_index(clock, &entries);
    }

    /// Bump `key`'s LRU clock (best-effort; called on successful load).
    fn touch(&self, key: &str) {
        let (clock, mut entries) = self.read_index();
        if let Some(e) = entries.get_mut(key) {
            let clock = clock + 1;
            e.1 = clock;
            self.write_index(clock, &entries);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dse::{drive, EvalEngine};
    use crate::opt::Space;
    use std::sync::Arc;

    fn tempdir(tag: &str) -> PathBuf {
        let d =
            std::env::temp_dir().join(format!("fifoadvisor_store_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn fig2_workload() -> Arc<Workload> {
        let bd = bench_suite::build("fig2");
        Arc::new(Workload::from_design_args(&bd.design, &[vec![16]]).unwrap())
    }

    fn run_engine(w: &Arc<Workload>, budget: usize) -> EvalEngine {
        let space = Space::from_workload(w);
        let mut ev = EvalEngine::for_workload(w.clone(), 1);
        ev.eval_baselines();
        let mut o = crate::opt::random::RandomSearch::new(21, false);
        drive(&mut o, &mut ev, &space, budget);
        ev
    }

    #[test]
    fn snapshot_roundtrips_and_warm_starts_with_zero_sims() {
        let w = fig2_workload();
        let dir = tempdir("roundtrip");
        let store = Store::new(dir.to_str().unwrap(), 64);
        let key = Store::key("fig2", &w, "fast", true, true);

        let cold = run_engine(&w, 80);
        assert!(cold.stats().sims > 0);
        let snap = Snapshot::capture("fig2", &cold);
        store.save(&key, &snap).unwrap();

        let loaded = store.load(&key).expect("saved snapshot must load");
        assert_eq!(loaded, snap, "decode(encode(snapshot)) must be identity");

        // Warm engine: apply, rerun identically → zero simulations and a
        // bit-identical history.
        let space = Space::from_workload(&w);
        let mut warm = EvalEngine::for_workload(w.clone(), 1);
        let n = loaded.apply(&mut warm).unwrap();
        assert_eq!(n, snap.memo.len());
        warm.eval_baselines();
        let mut o = crate::opt::random::RandomSearch::new(21, false);
        drive(&mut o, &mut warm, &space, 80);
        assert_eq!(warm.stats().sims, 0, "warm run must be a pure replay");
        let h = |e: &EvalEngine| {
            e.history
                .iter()
                .map(|p| (p.depths.clone(), p.latency, p.bram))
                .collect::<Vec<_>>()
        };
        assert_eq!(h(&cold), h(&warm));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_separates_regimes_and_workloads() {
        let bd = bench_suite::build("fig2");
        let w16 = Arc::new(Workload::from_design_args(&bd.design, &[vec![16]]).unwrap());
        let w8 = Arc::new(Workload::from_design_args(&bd.design, &[vec![8]]).unwrap());
        let base = Store::key("fig2", &w16, "fast", true, true);
        assert_eq!(base.len(), 16);
        assert_ne!(base, Store::key("fig2", &w8, "fast", true, true));
        assert_ne!(base, Store::key("fig2", &w16, "batched", true, true));
        assert_ne!(base, Store::key("fig2", &w16, "fast", false, true));
        assert_ne!(base, Store::key("fig2", &w16, "fast", true, false));
        assert_eq!(base, Store::key("fig2", &w16, "fast", true, true));
    }

    #[test]
    fn regime_mismatch_rejects_the_whole_snapshot() {
        let w = fig2_workload();
        let cold = run_engine(&w, 40);
        let snap = Snapshot::capture("fig2", &cold);
        // Wrong prune regime.
        let mut off = EvalEngine::for_workload(w.clone(), 1);
        off.set_prune(false);
        assert!(snap.apply(&mut off).is_err());
        assert_eq!(off.cache_len(), 0, "rejected snapshot must not import");
        // Garbled bounds fingerprint.
        let mut bad = snap.clone();
        bad.bounds_fp ^= 1;
        let mut fresh = EvalEngine::for_workload(w.clone(), 1);
        assert!(bad.apply(&mut fresh).is_err());
        // Garbled BRAM total (checksum-passing but wrong content).
        let mut bad = snap.clone();
        bad.memo[0].2 += 1;
        assert!(bad.apply(&mut fresh).is_err());
        assert_eq!(fresh.cache_len(), 0);
    }

    #[test]
    fn missing_and_corrupt_files_degrade_to_cold_start() {
        let w = fig2_workload();
        let dir = tempdir("corrupt");
        let store = Store::new(dir.to_str().unwrap(), 64);
        let key = Store::key("fig2", &w, "fast", true, true);
        assert!(store.load(&key).is_none(), "missing file is a silent miss");

        let cold = run_engine(&w, 40);
        store.save(&key, &Snapshot::capture("fig2", &cold)).unwrap();
        let path = dir.join(format!("{key}.json"));
        let good = fs::read_to_string(&path).unwrap();

        // Truncation.
        fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(store.load(&key).is_none());
        // Byte garble that still parses as JSON (digit flip) must be
        // caught by the checksum.
        let garbled = good.replacen("[[", "[[9", 1);
        fs::write(&path, &garbled).unwrap();
        assert!(store.load(&key).is_none());
        // Valid JSON, wrong shape.
        fs::write(&path, "{\"version\":1}").unwrap();
        assert!(store.load(&key).is_none());
        // Restore the good bytes: loads again.
        fs::write(&path, &good).unwrap();
        assert!(store.load(&key).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_eviction_drops_the_stalest_snapshot_first() {
        let w = fig2_workload();
        let dir = tempdir("lru");
        // A deliberately tiny budget: one snapshot fits, two do not
        // (max_mb granularity is too coarse, so build the store by hand).
        let store = Store {
            dir: dir.clone(),
            max_bytes: 1,
        };
        let cold = run_engine(&w, 40);
        let snap = Snapshot::capture("fig2", &cold);
        store.save("aaaa", &snap).unwrap();
        store.save("bbbb", &snap).unwrap();
        assert!(
            !dir.join("aaaa.json").exists(),
            "oldest snapshot must be evicted"
        );
        assert!(dir.join("bbbb.json").exists(), "newest snapshot survives");
        // Touching a key protects it: reload bbbb's entry, save a third.
        store.save("cccc", &snap).unwrap();
        assert!(!dir.join("bbbb.json").exists());
        assert!(dir.join("cccc.json").exists());
        let _ = fs::remove_dir_all(&dir);
    }
}
