//! Trace collection — the LightningSim "phase 1" analog.
//!
//! Executes a [`Design`]'s VM processes once under Kahn-process-network
//! semantics (unbounded channels, blocking reads) and records, per
//! process, the exact sequence of FIFO operations with the compute delays
//! between them. KPN determinism guarantees the recorded [`Trace`] is
//! independent of FIFO depths, so any depth assignment can later be
//! evaluated against the same trace ([`crate::sim`]) — this is the paper's
//! key enabler for millisecond-scale incremental re-simulation.

pub mod serde;
pub mod workload;

pub use workload::{Scenario, Workload, WorkloadError};

use crate::ir::{Design, Instr};
use std::collections::VecDeque;
use thiserror::Error;

/// One FIFO operation in a process's trace: `delay` compute cycles after
/// the previous operation, then a read or write on channel `chan`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceOp {
    /// Compute cycles between the previous FIFO op's completion and this
    /// op's earliest start (in addition to the II=1 spacing the simulator
    /// applies between consecutive ops).
    pub delay: u32,
    code: u32,
}

const WRITE_BIT: u32 = 1 << 31;

impl TraceOp {
    pub fn write(chan: usize, delay: u32) -> TraceOp {
        debug_assert!((chan as u32) < WRITE_BIT);
        TraceOp {
            delay,
            code: chan as u32 | WRITE_BIT,
        }
    }

    pub fn read(chan: usize, delay: u32) -> TraceOp {
        debug_assert!((chan as u32) < WRITE_BIT);
        TraceOp {
            delay,
            code: chan as u32,
        }
    }

    #[inline]
    pub fn chan(&self) -> usize {
        (self.code & !WRITE_BIT) as usize
    }

    #[inline]
    pub fn is_write(&self) -> bool {
        self.code & WRITE_BIT != 0
    }
}

/// Per-channel static+observed info carried by a trace.
#[derive(Debug, Clone)]
pub struct ChanInfo {
    pub name: String,
    pub width_bits: u32,
    pub group: Option<String>,
    pub depth_hint: Option<u32>,
    /// Total writes observed during execution (the paper's default upper
    /// bound for the FIFO's depth).
    pub writes: u64,
    /// Total reads observed.
    pub reads: u64,
}

/// The execution trace of a design: everything the simulator needs.
#[derive(Debug, Clone)]
pub struct Trace {
    pub design_name: String,
    pub channels: Vec<ChanInfo>,
    pub process_names: Vec<String>,
    /// Per-process FIFO operation sequences.
    pub ops: Vec<Vec<TraceOp>>,
    /// Per-process compute cycles *after* the last FIFO operation (a
    /// process's completion time includes trailing computation).
    pub tail_delays: Vec<u64>,
    /// Kernel arguments the trace was collected under (traces with
    /// data-dependent control flow are argument-specific — §IV-D).
    pub args: Vec<i64>,
}

impl Trace {
    /// Total FIFO operations across all processes.
    pub fn total_ops(&self) -> usize {
        self.ops.iter().map(|o| o.len()).sum()
    }

    /// Number of channels.
    pub fn num_fifos(&self) -> usize {
        self.channels.len()
    }

    /// Per-channel DSE upper bounds `u_i`: the designer-declared depth if
    /// present, otherwise the observed write count (both floored at 2).
    pub fn upper_bounds(&self) -> Vec<u32> {
        self.channels
            .iter()
            .map(|c| {
                let u = c
                    .depth_hint
                    .map(u64::from)
                    .unwrap_or(c.writes)
                    .min(u32::MAX as u64) as u32;
                u.max(2)
            })
            .collect()
    }

    /// The Baseline-Max configuration (paper §IV-A): every FIFO at its
    /// upper bound — fully buffers all traffic, deadlock-free by
    /// construction.
    pub fn baseline_max(&self) -> Vec<u32> {
        self.upper_bounds()
    }

    /// The Baseline-Min configuration: every FIFO at depth 2 (the Vitis
    /// default and the smallest practical size).
    pub fn baseline_min(&self) -> Vec<u32> {
        vec![2; self.channels.len()]
    }

    /// Group structure (channel indices per stream array / singleton).
    pub fn groups(&self) -> Vec<Vec<usize>> {
        let mut order: Vec<&str> = Vec::new();
        let mut map: std::collections::HashMap<&str, Vec<usize>> =
            std::collections::HashMap::new();
        let mut singles = Vec::new();
        for (id, ch) in self.channels.iter().enumerate() {
            match ch.group.as_deref() {
                Some(g) => {
                    if !map.contains_key(g) {
                        order.push(g);
                    }
                    map.entry(g).or_default().push(id);
                }
                None => singles.push(vec![id]),
            }
        }
        let mut out: Vec<Vec<usize>> = order.into_iter().map(|g| map.remove(g).unwrap()).collect();
        out.extend(singles);
        out.sort_by_key(|ids| ids[0]);
        out
    }
}

/// Channel ↔ process op-index maps, built once per trace — the lookup
/// structure behind delta-incremental re-simulation
/// ([`crate::sim::fast::FastSim`]).
///
/// For every channel it records which process writes/reads it (traces are
/// SPSC by construction) and *where* in that process's op sequence each
/// write/read ordinal sits; for every op it records its ordinal on its
/// channel. Together these answer, in O(log ops) per query, the two
/// questions incremental invalidation asks:
///
/// - "commits on channel `c` from ordinal `j` changed — from which op
///   index must the peer process be replayed?" (`wr_ops`/`rd_ops`), and
/// - "process `p` restarts at op `k` — what was the commit time of op
///   `k-1`?" (`op_ord` indexes the retained per-channel commit arrays).
#[derive(Debug, Clone)]
pub struct ChanOpIndex {
    /// Per channel: op indices (into the writer process's op sequence) of
    /// its writes, in write-ordinal order.
    pub wr_ops: Vec<Box<[u32]>>,
    /// Per channel: op indices of its reads in the reader process.
    pub rd_ops: Vec<Box<[u32]>>,
    /// Per channel: writer process id (`u32::MAX` if never written).
    pub writer: Vec<u32>,
    /// Per channel: reader process id (`u32::MAX` if never read).
    pub reader: Vec<u32>,
    /// Per process: the distinct channels it touches.
    pub proc_chans: Vec<Box<[u32]>>,
    /// Per process, per op index: the op's ordinal among that channel's
    /// same-kind ops (channel-wide, since traces are SPSC).
    pub op_ord: Vec<Box<[u32]>>,
}

impl ChanOpIndex {
    /// Build the index for a trace. O(total ops).
    pub fn build(trace: &Trace) -> ChanOpIndex {
        let nch = trace.channels.len();
        let nproc = trace.ops.len();
        let mut wr_ops: Vec<Vec<u32>> = vec![Vec::new(); nch];
        let mut rd_ops: Vec<Vec<u32>> = vec![Vec::new(); nch];
        let mut writer = vec![u32::MAX; nch];
        let mut reader = vec![u32::MAX; nch];
        let mut proc_chans: Vec<Box<[u32]>> = Vec::with_capacity(nproc);
        let mut op_ord: Vec<Box<[u32]>> = Vec::with_capacity(nproc);
        // Per-channel "last process that noted touching it" stamp, so the
        // distinct-channel lists build in O(ops) without a set.
        let mut touched_by = vec![u32::MAX; nch];
        for (pid, ops) in trace.ops.iter().enumerate() {
            let mut touched: Vec<u32> = Vec::new();
            let mut ord = vec![0u32; ops.len()].into_boxed_slice();
            for (k, op) in ops.iter().enumerate() {
                let ch = op.chan();
                if op.is_write() {
                    writer[ch] = pid as u32;
                    ord[k] = wr_ops[ch].len() as u32;
                    wr_ops[ch].push(k as u32);
                } else {
                    reader[ch] = pid as u32;
                    ord[k] = rd_ops[ch].len() as u32;
                    rd_ops[ch].push(k as u32);
                }
                if touched_by[ch] != pid as u32 {
                    touched_by[ch] = pid as u32;
                    touched.push(ch as u32);
                }
            }
            proc_chans.push(touched.into_boxed_slice());
            op_ord.push(ord);
        }
        ChanOpIndex {
            wr_ops: wr_ops.into_iter().map(Vec::into_boxed_slice).collect(),
            rd_ops: rd_ops.into_iter().map(Vec::into_boxed_slice).collect(),
            writer,
            reader,
            proc_chans,
            op_ord,
        }
    }
}

/// Trace collection failure.
#[derive(Debug, Error)]
pub enum TraceError {
    /// The design deadlocks even with unbounded FIFOs: some process reads
    /// a value that is never written. This is a design bug independent of
    /// FIFO sizing.
    #[error("KPN deadlock during trace collection: processes {stuck:?} blocked reading channels {channels:?}")]
    KpnDeadlock {
        stuck: Vec<String>,
        channels: Vec<String>,
    },
    /// Two processes write (or read) the same channel; HLS streams are
    /// single-producer single-consumer.
    #[error("channel '{chan}' has multiple {role}s (processes '{first}' and '{second}')")]
    NotSpsc {
        chan: String,
        role: &'static str,
        first: String,
        second: String,
    },
    /// Trace exceeded the op budget (runaway loop protection).
    #[error("trace exceeded {limit} FIFO operations; runaway design?")]
    TooLong { limit: usize },
}

/// Collect the execution trace of `design` for kernel arguments `args`.
///
/// Runs all processes concurrently (round-robin with wake-on-write) under
/// unbounded-FIFO semantics.
pub fn collect_trace(design: &Design, args: &[i64]) -> Result<Trace, TraceError> {
    collect_trace_bounded(design, args, 100_000_000)
}

/// [`collect_trace`] with an explicit op budget.
pub fn collect_trace_bounded(
    design: &Design,
    args: &[i64],
    max_ops: usize,
) -> Result<Trace, TraceError> {
    assert_eq!(
        args.len(),
        design.num_args,
        "design '{}' expects {} args, got {}",
        design.name,
        design.num_args,
        args.len()
    );

    let nch = design.channels.len();
    let mut queues: Vec<VecDeque<i64>> = vec![VecDeque::new(); nch];
    let mut writes = vec![0u64; nch];
    let mut reads = vec![0u64; nch];
    let mut writer_of: Vec<Option<usize>> = vec![None; nch];
    let mut reader_of: Vec<Option<usize>> = vec![None; nch];
    let mut ops: Vec<Vec<TraceOp>> = vec![Vec::new(); design.processes.len()];
    let mut total_ops = 0usize;

    let mut states: Vec<ProcState> = design
        .processes
        .iter()
        .map(|p| ProcState::new(p.num_vars, &p.body))
        .collect();

    // Ready list + per-channel wait list (procs blocked reading it).
    let mut ready: VecDeque<usize> = (0..states.len()).collect();
    let mut in_ready: Vec<bool> = vec![true; states.len()];
    let mut waiting: Vec<Vec<usize>> = vec![Vec::new(); nch];

    while let Some(pid) = ready.pop_front() {
        in_ready[pid] = false;
        let proc = &design.processes[pid];

        loop {
            match states[pid].step(&proc.body, args) {
                StepOut::Write(ch, value) => {
                    match writer_of[ch] {
                        None => writer_of[ch] = Some(pid),
                        Some(p) if p == pid => {}
                        Some(p) => {
                            return Err(TraceError::NotSpsc {
                                chan: design.channels[ch].name.clone(),
                                role: "writer",
                                first: design.processes[p].name.clone(),
                                second: proc.name.clone(),
                            })
                        }
                    }
                    queues[ch].push_back(value);
                    writes[ch] += 1;
                    let delay = states[pid].take_delay();
                    ops[pid].push(TraceOp::write(ch, delay));
                    total_ops += 1;
                    if total_ops > max_ops {
                        return Err(TraceError::TooLong { limit: max_ops });
                    }
                    // Wake readers blocked on this channel.
                    for w in waiting[ch].drain(..) {
                        if !in_ready[w] {
                            in_ready[w] = true;
                            ready.push_back(w);
                        }
                    }
                }
                StepOut::TryRead(ch, var) => {
                    match reader_of[ch] {
                        None => reader_of[ch] = Some(pid),
                        Some(p) if p == pid => {}
                        Some(p) => {
                            return Err(TraceError::NotSpsc {
                                chan: design.channels[ch].name.clone(),
                                role: "reader",
                                first: design.processes[p].name.clone(),
                                second: proc.name.clone(),
                            })
                        }
                    }
                    if let Some(v) = queues[ch].pop_front() {
                        states[pid].complete_read(var, v);
                        reads[ch] += 1;
                        let delay = states[pid].take_delay();
                        ops[pid].push(TraceOp::read(ch, delay));
                        total_ops += 1;
                        if total_ops > max_ops {
                            return Err(TraceError::TooLong { limit: max_ops });
                        }
                    } else {
                        // Block: park on the channel, yield.
                        waiting[ch].push(pid);
                        break;
                    }
                }
                StepOut::Done => break,
            }
        }
    }

    // All ready work drained: either everything finished or we have a KPN
    // deadlock (readers starved forever).
    let stuck: Vec<usize> = states
        .iter()
        .enumerate()
        .filter(|(_, s)| !s.is_done())
        .map(|(i, _)| i)
        .collect();
    if !stuck.is_empty() {
        let mut chans: Vec<String> = Vec::new();
        for (ch, ws) in waiting.iter().enumerate() {
            if !ws.is_empty() {
                chans.push(design.channels[ch].name.clone());
            }
        }
        return Err(TraceError::KpnDeadlock {
            stuck: stuck
                .into_iter()
                .map(|i| design.processes[i].name.clone())
                .collect(),
            channels: chans,
        });
    }

    let channels = design
        .channels
        .iter()
        .enumerate()
        .map(|(i, c)| ChanInfo {
            name: c.name.clone(),
            width_bits: c.width_bits,
            group: c.group.clone(),
            depth_hint: c.depth_hint,
            writes: writes[i],
            reads: reads[i],
        })
        .collect();

    let tail_delays = states.iter().map(|s| s.pending_delay).collect();

    Ok(Trace {
        design_name: design.name.clone(),
        channels,
        process_names: design.processes.iter().map(|p| p.name.clone()).collect(),
        ops,
        tail_delays,
        args: args.to_vec(),
    })
}

// ---------------------------------------------------------------------------
// Resumable VM interpreter
// ---------------------------------------------------------------------------

/// One level of the VM control stack.
#[derive(Debug)]
enum Frame {
    /// Straight-line block (process body or If arm): list index into the
    /// process body tree is re-resolved from the path each step; instead we
    /// store raw pointers via indices — see `FrameRef`.
    Block { pc: usize },
    Loop {
        pc: usize,
        var: usize,
        current: i64,
        remaining: i64,
    },
}

/// Because `Instr` trees are nested, frames record *which* instruction
/// list they execute via a lightweight path: the root body plus, per
/// frame, the child selector used to descend. We resolve the instruction
/// list on each access (cheap: bodies are shallow).
#[derive(Debug, Clone, Copy)]
enum Descend {
    LoopBody(usize),
    ThenBody(usize),
    ElseBody(usize),
}

struct ProcState {
    vars: Vec<i64>,
    frames: Vec<Frame>,
    path: Vec<Descend>,
    pending_delay: u64,
    pending_read: Option<(usize, usize)>, // (chan, var) of an issued-but-unfilled read
    done: bool,
}

enum StepOut {
    Write(usize, i64),
    TryRead(usize, usize),
    Done,
}

impl ProcState {
    fn new(num_vars: usize, _body: &[Instr]) -> ProcState {
        ProcState {
            vars: vec![0; num_vars],
            frames: vec![Frame::Block { pc: 0 }],
            path: Vec::new(),
            pending_delay: 0,
            pending_read: None,
            done: false,
        }
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn take_delay(&mut self) -> u32 {
        let d = self.pending_delay.min(u32::MAX as u64) as u32;
        self.pending_delay = 0;
        d
    }

    fn complete_read(&mut self, var: usize, value: i64) {
        self.vars[var] = value;
        debug_assert!(self.pending_read.is_some());
        self.pending_read = None;
        // Advance past the Read instruction.
        self.advance_pc();
    }

    fn advance_pc(&mut self) {
        match self.frames.last_mut().unwrap() {
            Frame::Block { pc } | Frame::Loop { pc, .. } => *pc += 1,
        }
    }

    /// Resolve the instruction list the top frame is executing.
    fn current_body<'a>(&self, root: &'a [Instr]) -> &'a [Instr] {
        let mut body = root;
        for d in &self.path {
            body = match (*d, body) {
                (Descend::LoopBody(i), b) => match &b[i] {
                    Instr::For { body, .. } => body,
                    _ => unreachable!("path desync"),
                },
                (Descend::ThenBody(i), b) => match &b[i] {
                    Instr::If { then_body, .. } => then_body,
                    _ => unreachable!("path desync"),
                },
                (Descend::ElseBody(i), b) => match &b[i] {
                    Instr::If { else_body, .. } => else_body,
                    _ => unreachable!("path desync"),
                },
            };
        }
        body
    }

    /// Run until the next FIFO side effect (or completion). Pure
    /// instructions (Set/Delay/For/If bookkeeping) are consumed inline.
    fn step(&mut self, root: &[Instr], args: &[i64]) -> StepOut {
        if self.done {
            return StepOut::Done;
        }
        loop {
            // If a read was issued and is still pending, re-issue it (the
            // scheduler calls us again once data might be available).
            if let Some((ch, var)) = self.pending_read {
                return StepOut::TryRead(ch, var);
            }

            let body = self.current_body(root);
            let frame = self.frames.last_mut().unwrap();
            let pc = match frame {
                Frame::Block { pc } | Frame::Loop { pc, .. } => *pc,
            };

            if pc >= body.len() {
                // Block finished: iterate the loop or pop the frame. The
                // loop bookkeeping is done in a narrow scope so the frame
                // borrow is released before touching `self.vars`.
                let loop_update = match frame {
                    Frame::Loop {
                        pc,
                        var,
                        current,
                        remaining,
                    } => {
                        *remaining -= 1;
                        *current += 1;
                        let continues = *remaining > 0;
                        if continues {
                            *pc = 0;
                        }
                        Some((*var, *current, continues))
                    }
                    Frame::Block { .. } => None,
                };
                let pop = match loop_update {
                    Some((var, cur, continues)) => {
                        self.vars[var] = cur;
                        !continues
                    }
                    None => true,
                };
                if pop {
                    self.frames.pop();
                    self.path.pop();
                    if self.frames.is_empty() {
                        self.done = true;
                        return StepOut::Done;
                    }
                    self.advance_pc();
                }
                continue;
            }

            match &body[pc] {
                Instr::Set(var, e) => {
                    self.vars[*var] = e.eval(args, &self.vars);
                    self.advance_pc();
                }
                Instr::Delay(e) => {
                    let d = e.eval(args, &self.vars).max(0) as u64;
                    self.pending_delay += d;
                    self.advance_pc();
                }
                Instr::Write(ch, e) => {
                    let v = e.eval(args, &self.vars);
                    let ch = *ch;
                    self.advance_pc();
                    return StepOut::Write(ch, v);
                }
                Instr::Read(ch, var) => {
                    // Do NOT advance pc: completion does (or we stay blocked).
                    self.pending_read = Some((*ch, *var));
                    return StepOut::TryRead(*ch, *var);
                }
                Instr::For {
                    var,
                    start,
                    count,
                    body: _,
                } => {
                    let n = count.eval(args, &self.vars);
                    let s = start.eval(args, &self.vars);
                    if n > 0 {
                        self.vars[*var] = s;
                        let var = *var;
                        self.path.push(Descend::LoopBody(pc));
                        self.frames.push(Frame::Loop {
                            pc: 0,
                            var,
                            current: s,
                            remaining: n,
                        });
                    } else {
                        self.advance_pc();
                    }
                }
                Instr::If { cond, .. } => {
                    let taken = cond.eval(args, &self.vars) != 0;
                    self.path.push(if taken {
                        Descend::ThenBody(pc)
                    } else {
                        Descend::ElseBody(pc)
                    });
                    self.frames.push(Frame::Block { pc: 0 });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DesignBuilder, Expr};

    /// The paper's Fig. 2 design: producer writes n to x then n to y;
    /// consumer alternates x/y reads.
    fn fig2_design() -> Design {
        let mut b = DesignBuilder::new("mult_by_2", 1);
        let x = b.channel("x", 32);
        let y = b.channel("y", 32);
        b.process("producer", |p| {
            p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
            p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
        });
        b.process("consumer", |p| {
            let sum = p.var();
            p.set(sum, Expr::c(0));
            p.for_expr(Expr::arg(0), |p, _| {
                let a = p.read(x);
                let bb = p.read(y);
                p.set(sum, Expr::var(sum).add(Expr::var(a)).add(Expr::var(bb)));
            });
        });
        b.build()
    }

    #[test]
    fn fig2_trace_counts_scale_with_n() {
        for n in [1i64, 4, 16] {
            let t = collect_trace(&fig2_design(), &[n]).unwrap();
            assert_eq!(t.channels[0].writes, n as u64);
            assert_eq!(t.channels[0].reads, n as u64);
            assert_eq!(t.channels[1].writes, n as u64);
            assert_eq!(t.total_ops(), 4 * n as usize);
            // producer ops: n writes to x then n to y, interleaving preserved
            let prod = &t.ops[0];
            assert!(prod[..n as usize].iter().all(|o| o.is_write() && o.chan() == 0));
            assert!(prod[n as usize..].iter().all(|o| o.is_write() && o.chan() == 1));
            // consumer alternates x,y
            let cons = &t.ops[1];
            for (i, op) in cons.iter().enumerate() {
                assert!(!op.is_write());
                assert_eq!(op.chan(), i % 2);
            }
        }
    }

    #[test]
    fn data_dependent_loop_bounds_from_stream_values() {
        // producer sends a count; consumer reads that many more values —
        // control flow not knowable statically (§II-A).
        let mut b = DesignBuilder::new("ddcf", 1);
        let c = b.channel("c", 32);
        let d = b.channel("d", 32);
        b.process("prod", |p| {
            p.write(c, Expr::arg(0));
            p.for_expr(Expr::arg(0), |p, i| p.write(d, Expr::var(i)));
        });
        b.process("cons", |p| {
            let n = p.read(c);
            p.for_expr(Expr::var(n), |p, _| {
                let _ = p.read(d);
            });
        });
        let design = b.build();
        let t5 = collect_trace(&design, &[5]).unwrap();
        assert_eq!(t5.channels[1].reads, 5);
        let t9 = collect_trace(&design, &[9]).unwrap();
        assert_eq!(t9.channels[1].reads, 9);
    }

    #[test]
    fn delays_accumulate_onto_next_op() {
        let mut b = DesignBuilder::new("dly", 0);
        let c = b.channel("c", 32);
        b.process("p", |p| {
            p.delay(10);
            p.delay(5);
            p.write(c, Expr::c(0));
            p.write(c, Expr::c(0));
        });
        b.process("q", |p| {
            let _ = p.read(c);
            let _ = p.read(c);
        });
        let t = collect_trace(&b.build(), &[]).unwrap();
        assert_eq!(t.ops[0][0].delay, 15);
        assert_eq!(t.ops[0][1].delay, 0);
    }

    #[test]
    fn kpn_deadlock_detected() {
        // consumer reads more than producer writes
        let mut b = DesignBuilder::new("starved", 0);
        let c = b.channel("c", 32);
        b.process("prod", |p| p.write(c, Expr::c(1)));
        b.process("cons", |p| {
            let _ = p.read(c);
            let _ = p.read(c);
        });
        match collect_trace(&b.build(), &[]) {
            Err(TraceError::KpnDeadlock { stuck, channels }) => {
                assert_eq!(stuck, vec!["cons".to_string()]);
                assert_eq!(channels, vec!["c".to_string()]);
            }
            other => panic!("expected KPN deadlock, got {other:?}"),
        }
    }

    #[test]
    fn spsc_violation_detected() {
        let mut b = DesignBuilder::new("mpsc", 0);
        let c = b.channel("c", 32);
        b.process("w1", |p| p.write(c, Expr::c(1)));
        b.process("w2", |p| p.write(c, Expr::c(2)));
        b.process("r", |p| {
            let _ = p.read(c);
            let _ = p.read(c);
        });
        match collect_trace(&b.build(), &[]) {
            Err(TraceError::NotSpsc { role, .. }) => assert_eq!(role, "writer"),
            other => panic!("expected SPSC violation, got {other:?}"),
        }
    }

    #[test]
    fn op_budget_enforced() {
        let mut b = DesignBuilder::new("big", 0);
        let c = b.channel("c", 32);
        b.process("p", |p| {
            p.for_n(1000, |p, _| p.write(c, Expr::c(0)));
        });
        b.process("q", |p| {
            p.for_n(1000, |p, _| {
                let _ = p.read(c);
            });
        });
        match collect_trace_bounded(&b.build(), &[], 100) {
            Err(TraceError::TooLong { limit }) => assert_eq!(limit, 100),
            other => panic!("expected TooLong, got {other:?}"),
        }
    }

    #[test]
    fn if_branches_affect_trace() {
        let mut b = DesignBuilder::new("br", 1);
        let c = b.channel("c", 32);
        b.process("p", |p| {
            p.if_(
                Expr::arg(0).lt(Expr::c(0)),
                |p| p.write(c, Expr::c(1)),
                |p| {
                    p.write(c, Expr::c(2));
                    p.write(c, Expr::c(3));
                },
            );
        });
        b.process("q", |p| {
            let n = p.var();
            p.set(n, Expr::arg(0).lt(Expr::c(0)));
            p.if_(
                Expr::var(n),
                |p| {
                    let _ = p.read(c);
                },
                |p| {
                    let _ = p.read(c);
                    let _ = p.read(c);
                },
            );
        });
        let d = b.build();
        assert_eq!(collect_trace(&d, &[-1]).unwrap().channels[0].writes, 1);
        assert_eq!(collect_trace(&d, &[1]).unwrap().channels[0].writes, 2);
    }

    #[test]
    fn upper_bounds_respect_hints_and_writes() {
        let mut b = DesignBuilder::new("ub", 0);
        let c = b.channel("c", 32); // no hint: bound = writes
        let d = b.channel_with_depth("d", 32, 64); // hint wins
        b.process("p", |p| {
            p.for_n(10, |p, _| p.write(c, Expr::c(0)));
            p.write(d, Expr::c(0));
        });
        b.process("q", |p| {
            p.for_n(10, |p, _| {
                let _ = p.read(c);
            });
            let _ = p.read(d);
        });
        let t = collect_trace(&b.build(), &[]).unwrap();
        assert_eq!(t.upper_bounds(), vec![10, 64]);
        assert_eq!(t.baseline_min(), vec![2, 2]);
    }

    #[test]
    fn chan_op_index_maps_ordinals_and_endpoints() {
        let t = collect_trace(&fig2_design(), &[4]).unwrap();
        let idx = ChanOpIndex::build(&t);
        // producer (pid 0) writes x then y; consumer (pid 1) alternates.
        assert_eq!(idx.writer, vec![0, 0]);
        assert_eq!(idx.reader, vec![1, 1]);
        // x's writes are producer ops 0..4; y's are 4..8.
        assert_eq!(idx.wr_ops[0].as_ref(), &[0, 1, 2, 3]);
        assert_eq!(idx.wr_ops[1].as_ref(), &[4, 5, 6, 7]);
        // consumer reads x at even op indices, y at odd.
        assert_eq!(idx.rd_ops[0].as_ref(), &[0, 2, 4, 6]);
        assert_eq!(idx.rd_ops[1].as_ref(), &[1, 3, 5, 7]);
        // Ordinals: op k of the consumer is ordinal k/2 on its channel.
        for k in 0..8usize {
            assert_eq!(idx.op_ord[1][k], (k / 2) as u32);
        }
        // Both processes touch both channels, listed once each.
        assert_eq!(idx.proc_chans[0].as_ref(), &[0, 1]);
        assert_eq!(idx.proc_chans[1].as_ref(), &[0, 1]);
    }

    #[test]
    fn groups_from_trace() {
        let mut b = DesignBuilder::new("grp", 0);
        let s = b.channel("s", 32);
        let arr = b.channel_array("a", 2, 32);
        b.process("p", |p| {
            p.write(s, Expr::c(0));
            for &c in &arr {
                p.write(c, Expr::c(0));
            }
        });
        b.process("q", |p| {
            let _ = p.read(s);
            for &c in &arr {
                let _ = p.read(c);
            }
        });
        let t = collect_trace(&b.build(), &[]).unwrap();
        assert_eq!(t.groups(), vec![vec![0], vec![1, 2]]);
    }
}
