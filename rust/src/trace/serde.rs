//! Trace (de)serialization: save a collected trace to JSON and reload it
//! later, so expensive software executions (the LightningSim phase-1
//! pass) are cached across tool invocations — and so traces can be
//! produced by external frontends.

use super::{ChanInfo, Trace, TraceOp};
use crate::util::Json;
use anyhow::{anyhow, Context, Result};

/// Serialize a trace to a JSON value.
pub fn trace_to_json(t: &Trace) -> Json {
    let channels = t
        .channels
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("name", Json::Str(c.name.clone())),
                ("width_bits", Json::Num(c.width_bits as f64)),
                (
                    "group",
                    c.group
                        .as_ref()
                        .map(|g| Json::Str(g.clone()))
                        .unwrap_or(Json::Null),
                ),
                (
                    "depth_hint",
                    c.depth_hint
                        .map(|d| Json::Num(d as f64))
                        .unwrap_or(Json::Null),
                ),
                ("writes", Json::Num(c.writes as f64)),
                ("reads", Json::Num(c.reads as f64)),
            ])
        })
        .collect();
    // Ops are flattened per process as [delay, signed_chan] pairs where
    // writes are encoded as (chan + 1) and reads as -(chan + 1).
    let ops = t
        .ops
        .iter()
        .map(|po| {
            let mut flat = Vec::with_capacity(po.len() * 2);
            for op in po {
                flat.push(Json::Num(op.delay as f64));
                let code = (op.chan() as i64 + 1) * if op.is_write() { 1 } else { -1 };
                flat.push(Json::Num(code as f64));
            }
            Json::Arr(flat)
        })
        .collect();
    Json::obj(vec![
        ("design_name", Json::Str(t.design_name.clone())),
        ("channels", Json::Arr(channels)),
        (
            "process_names",
            Json::Arr(t.process_names.iter().map(|n| Json::Str(n.clone())).collect()),
        ),
        ("ops", Json::Arr(ops)),
        (
            "tail_delays",
            Json::Arr(t.tail_delays.iter().map(|&d| Json::Num(d as f64)).collect()),
        ),
        (
            "args",
            Json::Arr(t.args.iter().map(|&a| Json::Num(a as f64)).collect()),
        ),
    ])
}

/// Deserialize a trace from JSON.
pub fn trace_from_json(j: &Json) -> Result<Trace> {
    let get = |k: &str| j.get(k).ok_or_else(|| anyhow!("trace json: missing '{k}'"));
    let design_name = get("design_name")?
        .as_str()
        .ok_or_else(|| anyhow!("design_name not a string"))?
        .to_string();
    let channels = get("channels")?
        .as_arr()
        .ok_or_else(|| anyhow!("channels not an array"))?
        .iter()
        .map(|c| -> Result<ChanInfo> {
            Ok(ChanInfo {
                name: c
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("channel name"))?
                    .to_string(),
                width_bits: c
                    .get("width_bits")
                    .and_then(|v| v.as_u64())
                    .ok_or_else(|| anyhow!("width_bits"))? as u32,
                group: c.get("group").and_then(|v| v.as_str()).map(str::to_string),
                depth_hint: c.get("depth_hint").and_then(|v| v.as_u64()).map(|d| d as u32),
                writes: c
                    .get("writes")
                    .and_then(|v| v.as_u64())
                    .ok_or_else(|| anyhow!("writes"))?,
                reads: c
                    .get("reads")
                    .and_then(|v| v.as_u64())
                    .ok_or_else(|| anyhow!("reads"))?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let process_names = get("process_names")?
        .as_arr()
        .ok_or_else(|| anyhow!("process_names"))?
        .iter()
        .map(|n| {
            n.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("process name"))
        })
        .collect::<Result<Vec<_>>>()?;
    let nch = channels.len();
    let ops = get("ops")?
        .as_arr()
        .ok_or_else(|| anyhow!("ops"))?
        .iter()
        .map(|po| -> Result<Vec<TraceOp>> {
            let flat = po.as_arr().ok_or_else(|| anyhow!("process ops"))?;
            if flat.len() % 2 != 0 {
                return Err(anyhow!("odd op stream length"));
            }
            flat.chunks(2)
                .map(|pair| -> Result<TraceOp> {
                    let delay = pair[0]
                        .as_u64()
                        .ok_or_else(|| anyhow!("op delay"))? as u32;
                    let code = pair[1]
                        .as_f64()
                        .ok_or_else(|| anyhow!("op code"))? as i64;
                    if code == 0 || code.unsigned_abs() as usize > nch {
                        return Err(anyhow!("op code {code} out of range"));
                    }
                    let chan = (code.unsigned_abs() - 1) as usize;
                    Ok(if code > 0 {
                        TraceOp::write(chan, delay)
                    } else {
                        TraceOp::read(chan, delay)
                    })
                })
                .collect()
        })
        .collect::<Result<Vec<_>>>()?;
    let tail_delays = get("tail_delays")?
        .as_arr()
        .ok_or_else(|| anyhow!("tail_delays"))?
        .iter()
        .map(|d| d.as_u64().ok_or_else(|| anyhow!("tail delay")))
        .collect::<Result<Vec<_>>>()?;
    let args = get("args")?
        .as_arr()
        .ok_or_else(|| anyhow!("args"))?
        .iter()
        .map(|a| {
            a.as_f64()
                .map(|v| v as i64)
                .ok_or_else(|| anyhow!("arg value"))
        })
        .collect::<Result<Vec<_>>>()?;
    if ops.len() != process_names.len() || tail_delays.len() != process_names.len() {
        return Err(anyhow!("process arity mismatch"));
    }
    Ok(Trace {
        design_name,
        channels,
        process_names,
        ops,
        tail_delays,
        args,
    })
}

/// Save a trace to a file.
pub fn save(t: &Trace, path: &str) -> Result<()> {
    crate::report::write_file(path, &trace_to_json(t).to_string_compact())
        .with_context(|| format!("writing {path}"))
}

/// Load a trace from a file.
pub fn load(path: &str) -> Result<Trace> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let j = Json::parse(&text).context("parsing trace json")?;
    trace_from_json(&j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    #[test]
    fn roundtrip_preserves_simulation() {
        for name in ["fig2", "gesummv", "flowgnn_pna"] {
            let bd = bench_suite::build(name);
            let t = collect_trace(&bd.design, &bd.args).unwrap();
            let j = trace_to_json(&t);
            let t2 = trace_from_json(&Json::parse(&j.to_string_compact()).unwrap()).unwrap();
            assert_eq!(t.total_ops(), t2.total_ops(), "{name}");
            assert_eq!(t.args, t2.args);
            let cfg = t.baseline_max();
            let l1 = FastSim::new(Arc::new(t)).simulate(&cfg).latency();
            let l2 = FastSim::new(Arc::new(t2)).simulate(&cfg).latency();
            assert_eq!(l1, l2, "{name}");
        }
    }

    #[test]
    fn corrupt_inputs_rejected() {
        assert!(trace_from_json(&Json::Null).is_err());
        let j = Json::obj(vec![("design_name", Json::Str("x".into()))]);
        assert!(trace_from_json(&j).is_err());
        // Op code out of range.
        let bd = bench_suite::build("fig2");
        let t = collect_trace(&bd.design, &bd.args).unwrap();
        let mut text = trace_to_json(&t).to_string_compact();
        text = text.replace("\"ops\":[[0,1", "\"ops\":[[0,99");
        let j = Json::parse(&text).unwrap();
        assert!(trace_from_json(&j).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let bd = bench_suite::build("fig2");
        let t = collect_trace(&bd.design, &bd.args).unwrap();
        let path = "/tmp/fifoadvisor_trace_test.json";
        save(&t, path).unwrap();
        let t2 = load(path).unwrap();
        assert_eq!(t.total_ops(), t2.total_ops());
        std::fs::remove_file(path).ok();
    }
}
