//! Multi-trace **workloads**: a named set of traces of the *same design*
//! collected under different kernel arguments, with per-scenario weights.
//!
//! Traces of data-dependent designs are argument-specific (§IV-D,
//! FlowGNN-PNA): a FIFO configuration sized against one input can stall
//! or deadlock on another. A [`Workload`] is the unit of
//! *scenario-robust* sizing — the whole evaluation stack
//! ([`crate::sim::scenario::ScenarioSim`], [`crate::dse::EvalEngine`])
//! evaluates every candidate configuration against every scenario and
//! reports worst-case (or weighted) latency, with deadlock in *any*
//! scenario making the configuration infeasible.
//!
//! Construction validates that all scenarios share one channel topology
//! (names, widths, groups, depth hints) and one process set, so channel
//! and process indices mean the same thing in every scenario. Merged
//! per-channel [`upper_bounds`](Workload::upper_bounds) (and therefore
//! Baseline-Max) are the max over scenarios — the smallest sizing that is
//! deadlock-free by construction on every input.
//!
//! [`Workload::single`] wraps one trace with zero semantic change: every
//! single-trace call site ports mechanically, and the simulator takes the
//! exact single-trace fast path.

use super::{collect_trace, Trace, TraceError};
use crate::ir::Design;
use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;
use thiserror::Error;

/// One scenario of a workload: a trace of the design under one argument
/// vector, with a report-friendly name and an aggregation weight.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    /// Relative weight for weighted-latency aggregation (must be finite
    /// and positive; ignored by the worst-case objective).
    pub weight: f64,
    pub trace: Arc<Trace>,
}

/// Workload construction failure.
#[derive(Debug, Error)]
pub enum WorkloadError {
    #[error("workload needs at least one scenario")]
    Empty,
    #[error("scenario '{scenario}' does not match the workload topology: {detail}")]
    TopologyMismatch { scenario: String, detail: String },
    #[error("scenario '{scenario}': design '{design}' expects {expected} args, got {got}")]
    ArgCount {
        scenario: String,
        design: String,
        expected: usize,
        got: usize,
    },
    #[error("scenario '{scenario}': trace collection failed: {source}")]
    Trace {
        scenario: String,
        #[source]
        source: TraceError,
    },
    #[error("duplicate scenario name '{name}'")]
    DuplicateName { name: String },
    #[error("scenario '{scenario}': weight {weight} must be finite and positive")]
    BadWeight { scenario: String, weight: f64 },
}

/// A validated set of scenarios over one design.
#[derive(Debug, Clone)]
pub struct Workload {
    design_name: String,
    scenarios: Vec<Scenario>,
    /// Construction diagnostics (e.g. duplicate-scenario folding) for
    /// the CLI's note mechanism. Not serialized.
    notes: Vec<String>,
}

impl Workload {
    /// Wrap one trace as a single-scenario workload (weight 1). This is
    /// the mechanical port for every pre-workload call site; evaluation
    /// of a single-scenario workload is bit-identical to evaluating the
    /// trace directly.
    pub fn single(trace: Arc<Trace>) -> Workload {
        Workload {
            design_name: trace.design_name.clone(),
            scenarios: vec![Scenario {
                name: "default".into(),
                weight: 1.0,
                trace,
            }],
            notes: Vec::new(),
        }
    }

    /// Build a workload from already-collected scenarios, validating
    /// non-emptiness, unique names, positive weights, and identical
    /// channel/process topology across scenarios. Scenarios whose
    /// kernel-argument vectors are byte-identical to an earlier sibling
    /// (same trace shape — execution is argument-deterministic, so the
    /// traces are too) are folded into it: the first occurrence keeps
    /// its name, weights add, and a [`note`](Self::notes) records the
    /// fold — simulating exact duplicates buys nothing.
    pub fn new(scenarios: Vec<Scenario>) -> Result<Workload, WorkloadError> {
        let first = scenarios.first().ok_or(WorkloadError::Empty)?;
        let reference = Arc::clone(&first.trace);
        let design_name = reference.design_name.clone();
        for (i, s) in scenarios.iter().enumerate() {
            if scenarios[..i].iter().any(|p| p.name == s.name) {
                return Err(WorkloadError::DuplicateName {
                    name: s.name.clone(),
                });
            }
            if !(s.weight.is_finite() && s.weight > 0.0) {
                return Err(WorkloadError::BadWeight {
                    scenario: s.name.clone(),
                    weight: s.weight,
                });
            }
            check_topology(&reference, s)?;
        }
        let mut notes = Vec::new();
        let mut kept: Vec<Scenario> = Vec::with_capacity(scenarios.len());
        for s in scenarios {
            match kept.iter_mut().find(|p| {
                p.trace.args == s.trace.args && p.trace.total_ops() == s.trace.total_ops()
            }) {
                Some(p) => {
                    p.weight += s.weight;
                    notes.push(format!(
                        "scenario '{}' duplicates '{}' (identical args {:?}); \
                         folded its weight instead of simulating it twice",
                        s.name, p.name, s.trace.args
                    ));
                }
                None => kept.push(s),
            }
        }
        Ok(Workload {
            design_name,
            scenarios: kept,
            notes,
        })
    }

    /// Collect one trace per `(name, args)` pair (uniform weight 1).
    /// Argument arity is checked against the design up front.
    /// Byte-identical duplicate arg vectors are folded *before* trace
    /// collection (keep-first, weights add, a note records the fold),
    /// so duplicates cost neither a trace run nor a simulation lane.
    pub fn from_design(
        design: &Design,
        scenarios: &[(String, Vec<i64>)],
    ) -> Result<Workload, WorkloadError> {
        let mut deduped: Vec<(String, Vec<i64>, f64)> = Vec::with_capacity(scenarios.len());
        let mut notes = Vec::new();
        for (name, args) in scenarios {
            if args.len() != design.num_args {
                return Err(WorkloadError::ArgCount {
                    scenario: name.clone(),
                    design: design.name.clone(),
                    expected: design.num_args,
                    got: args.len(),
                });
            }
            match deduped.iter_mut().find(|(_, a, _)| a == args) {
                Some((first, _, w)) => {
                    *w += 1.0;
                    notes.push(format!(
                        "scenario '{name}' duplicates '{first}' (identical args {args:?}); \
                         folded its weight instead of simulating it twice"
                    ));
                }
                None => deduped.push((name.clone(), args.clone(), 1.0)),
            }
        }
        let mut out = Vec::with_capacity(deduped.len());
        for (name, args, weight) in deduped {
            let trace = collect_trace(design, &args).map_err(|source| WorkloadError::Trace {
                scenario: name.clone(),
                source,
            })?;
            out.push(Scenario {
                name,
                weight,
                trace: Arc::new(trace),
            });
        }
        let mut w = Self::new(out)?;
        w.notes.extend(notes);
        Ok(w)
    }

    /// [`from_design`](Self::from_design) with auto-generated scenario
    /// names `s0`, `s1`, … (the CLI's repeatable `--args` path).
    pub fn from_design_args(
        design: &Design,
        arg_sets: &[Vec<i64>],
    ) -> Result<Workload, WorkloadError> {
        let named: Vec<(String, Vec<i64>)> = arg_sets
            .iter()
            .enumerate()
            .map(|(i, a)| (format!("s{i}"), a.clone()))
            .collect();
        Self::from_design(design, &named)
    }

    /// The common design name of all scenarios.
    pub fn design_name(&self) -> &str {
        &self.design_name
    }

    /// All scenarios, in construction order.
    pub fn scenarios(&self) -> &[Scenario] {
        &self.scenarios
    }

    pub fn num_scenarios(&self) -> usize {
        self.scenarios.len()
    }

    pub fn is_single(&self) -> bool {
        self.scenarios.len() == 1
    }

    /// The first scenario's trace — the topology reference (and, for
    /// single-scenario workloads, *the* trace).
    pub fn primary(&self) -> &Arc<Trace> {
        &self.scenarios[0].trace
    }

    /// Number of channels (identical across scenarios).
    pub fn num_fifos(&self) -> usize {
        self.primary().channels.len()
    }

    /// Total trace ops across all scenarios.
    pub fn total_ops(&self) -> usize {
        self.scenarios.iter().map(|s| s.trace.total_ops()).sum()
    }

    /// Per-scenario aggregation weights.
    pub fn weights(&self) -> Vec<f64> {
        self.scenarios.iter().map(|s| s.weight).collect()
    }

    /// Merged per-channel DSE upper bounds `u_i`: the max over scenarios
    /// of each trace's upper bound (designer hint, else observed writes).
    pub fn upper_bounds(&self) -> Vec<u32> {
        let mut out = self.primary().upper_bounds();
        for s in &self.scenarios[1..] {
            for (o, u) in out.iter_mut().zip(s.trace.upper_bounds()) {
                *o = (*o).max(u);
            }
        }
        out
    }

    /// The scenario-robust Baseline-Max: every FIFO at its merged upper
    /// bound — deadlock-free by construction on every scenario.
    pub fn baseline_max(&self) -> Vec<u32> {
        self.upper_bounds()
    }

    /// Baseline-Min: depth 2 everywhere (scenario-independent).
    pub fn baseline_min(&self) -> Vec<u32> {
        self.primary().baseline_min()
    }

    /// Construction diagnostics (duplicate-scenario folds and the like)
    /// for the CLI's `note:` mechanism.
    pub fn notes(&self) -> &[String] {
        &self.notes
    }

    /// The sub-workload over the scenarios at `keep` (indices into
    /// [`scenarios`](Self::scenarios), construction order preserved,
    /// weights/names intact). A non-empty subset of a valid workload is
    /// valid by construction, so no re-validation runs.
    ///
    /// Panics if `keep` is empty or out of range — callers distilling a
    /// bank always keep at least one scenario.
    pub fn subset(&self, keep: &[usize]) -> Workload {
        assert!(!keep.is_empty(), "workload subset must keep a scenario");
        Workload {
            design_name: self.design_name.clone(),
            scenarios: keep.iter().map(|&i| self.scenarios[i].clone()).collect(),
            notes: Vec::new(),
        }
    }

    /// Concatenate two workloads' scenario sets through full
    /// [`new`](Self::new) validation (same topology required; duplicate
    /// names rejected; duplicate arg vectors folded with a note).
    pub fn merge(&self, other: &Workload) -> Result<Workload, WorkloadError> {
        let mut all = self.scenarios.clone();
        all.extend(other.scenarios.iter().cloned());
        Self::new(all)
    }

    // -----------------------------------------------------------------
    // JSON serde
    // -----------------------------------------------------------------

    /// Serialize the whole scenario set (each scenario embeds its trace
    /// in the [`super::serde`] format).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("design_name", Json::Str(self.design_name.clone())),
            (
                "scenarios",
                Json::Arr(
                    self.scenarios
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("name", Json::Str(s.name.clone())),
                                ("weight", Json::Num(s.weight)),
                                ("trace", super::serde::trace_to_json(&s.trace)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Deserialize and re-validate a workload.
    pub fn from_json(j: &Json) -> Result<Workload> {
        let arr = j
            .get("scenarios")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("workload json: missing 'scenarios' array"))?;
        let mut scenarios = Vec::with_capacity(arr.len());
        for (i, sj) in arr.iter().enumerate() {
            let name = sj
                .get("name")
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .unwrap_or_else(|| format!("s{i}"));
            let weight = sj.get("weight").and_then(|v| v.as_f64()).unwrap_or(1.0);
            let tj = sj
                .get("trace")
                .ok_or_else(|| anyhow!("scenario '{name}': missing 'trace'"))?;
            let trace = super::serde::trace_from_json(tj)
                .with_context(|| format!("scenario '{name}'"))?;
            scenarios.push(Scenario {
                name,
                weight,
                trace: Arc::new(trace),
            });
        }
        let w = Workload::new(scenarios)?;
        if let Some(dn) = j.get("design_name").and_then(|v| v.as_str()) {
            if dn != w.design_name {
                return Err(anyhow!(
                    "workload design_name '{dn}' does not match its traces' '{}'",
                    w.design_name
                ));
            }
        }
        Ok(w)
    }

    /// Save the workload to a file. Crash-safe: the write routes
    /// through [`report::write_file`](crate::report::write_file) →
    /// [`util::atomic_write`](crate::util::atomic_write) (temp + fsync
    /// + rename), so an interrupted save never leaves a torn or empty
    /// workload file behind.
    pub fn save(&self, path: &str) -> Result<()> {
        crate::report::write_file(path, &self.to_json().to_string_compact())
            .with_context(|| format!("writing {path}"))
    }

    /// Load and validate a workload from a file.
    pub fn load(path: &str) -> Result<Workload> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let j = Json::parse(&text).context("parsing workload json")?;
        Self::from_json(&j)
    }
}

fn check_topology(reference: &Trace, s: &Scenario) -> Result<(), WorkloadError> {
    let t = &s.trace;
    let err = |detail: String| WorkloadError::TopologyMismatch {
        scenario: s.name.clone(),
        detail,
    };
    if t.design_name != reference.design_name {
        return Err(err(format!(
            "design '{}' vs '{}'",
            t.design_name, reference.design_name
        )));
    }
    if t.channels.len() != reference.channels.len() {
        return Err(err(format!(
            "{} channels vs {}",
            t.channels.len(),
            reference.channels.len()
        )));
    }
    for (a, b) in reference.channels.iter().zip(&t.channels) {
        if a.name != b.name
            || a.width_bits != b.width_bits
            || a.group != b.group
            || a.depth_hint != b.depth_hint
        {
            return Err(err(format!(
                "channel '{}' differs in name/width/group/depth hint",
                a.name
            )));
        }
    }
    if t.process_names != reference.process_names {
        return Err(err("process set differs".into()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;

    fn fig2_workload(ns: &[i64]) -> Workload {
        let bd = bench_suite::build("fig2");
        let named: Vec<(String, Vec<i64>)> =
            ns.iter().map(|&n| (format!("n{n}"), vec![n])).collect();
        Workload::from_design(&bd.design, &named).unwrap()
    }

    #[test]
    fn single_wraps_one_trace() {
        let bd = bench_suite::build("fig2");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let w = Workload::single(t.clone());
        assert!(w.is_single());
        assert_eq!(w.num_fifos(), t.num_fifos());
        assert_eq!(w.upper_bounds(), t.upper_bounds());
        assert_eq!(w.baseline_max(), t.baseline_max());
        assert_eq!(w.baseline_min(), t.baseline_min());
        assert_eq!(w.total_ops(), t.total_ops());
    }

    #[test]
    fn merged_bounds_are_max_over_scenarios() {
        let w = fig2_workload(&[8, 16, 12]);
        assert_eq!(w.num_scenarios(), 3);
        // fig2 x/y write counts equal n, so the merged bound is the
        // largest scenario's.
        assert_eq!(w.upper_bounds(), vec![16, 16]);
        // Each scenario keeps its own bound.
        assert_eq!(w.scenarios()[0].trace.upper_bounds(), vec![8, 8]);
    }

    #[test]
    fn arg_count_mismatch_rejected() {
        let bd = bench_suite::build("fig2");
        let err = Workload::from_design(
            &bd.design,
            &[("a".into(), vec![8]), ("b".into(), vec![8, 9])],
        )
        .unwrap_err();
        assert!(matches!(
            err,
            WorkloadError::ArgCount {
                expected: 1,
                got: 2,
                ..
            }
        ));
    }

    #[test]
    fn empty_duplicate_and_bad_weight_rejected() {
        assert!(matches!(
            Workload::new(vec![]).unwrap_err(),
            WorkloadError::Empty
        ));
        let bd = bench_suite::build("fig2");
        let t = Arc::new(collect_trace(&bd.design, &[8]).unwrap());
        let dup = Workload::new(vec![
            Scenario {
                name: "x".into(),
                weight: 1.0,
                trace: t.clone(),
            },
            Scenario {
                name: "x".into(),
                weight: 1.0,
                trace: t.clone(),
            },
        ]);
        assert!(matches!(
            dup.unwrap_err(),
            WorkloadError::DuplicateName { .. }
        ));
        let bad = Workload::new(vec![Scenario {
            name: "x".into(),
            weight: 0.0,
            trace: t,
        }]);
        assert!(matches!(bad.unwrap_err(), WorkloadError::BadWeight { .. }));
    }

    #[test]
    fn duplicate_args_fold_with_note() {
        let bd = bench_suite::build("fig2");
        let w = Workload::from_design(
            &bd.design,
            &[
                ("a".into(), vec![8]),
                ("b".into(), vec![16]),
                ("c".into(), vec![8]),
            ],
        )
        .unwrap();
        assert_eq!(w.num_scenarios(), 2, "duplicate args must fold");
        assert_eq!(w.scenarios()[0].name, "a");
        assert_eq!(w.scenarios()[0].weight, 2.0, "weights add on fold");
        assert_eq!(w.scenarios()[1].weight, 1.0);
        assert_eq!(w.notes().len(), 1);
        assert!(w.notes()[0].contains("'c'") && w.notes()[0].contains("'a'"));
        // Folding preserves the merged bounds.
        assert_eq!(w.upper_bounds(), fig2_workload(&[8, 16]).upper_bounds());
        // The same fold happens on the pre-collected path.
        let t8 = Arc::new(collect_trace(&bd.design, &[8]).unwrap());
        let t8b = Arc::new(collect_trace(&bd.design, &[8]).unwrap());
        let w2 = Workload::new(vec![
            Scenario {
                name: "x".into(),
                weight: 1.5,
                trace: t8,
            },
            Scenario {
                name: "y".into(),
                weight: 0.5,
                trace: t8b,
            },
        ])
        .unwrap();
        assert_eq!(w2.num_scenarios(), 1);
        assert_eq!(w2.scenarios()[0].weight, 2.0);
        assert_eq!(w2.notes().len(), 1);
    }

    #[test]
    fn subset_and_merge() {
        let w = fig2_workload(&[8, 16, 12]);
        let sub = w.subset(&[2, 0]);
        assert_eq!(sub.num_scenarios(), 2);
        assert_eq!(sub.scenarios()[0].name, "n12");
        assert_eq!(sub.scenarios()[1].name, "n8");
        assert_eq!(sub.upper_bounds(), vec![12, 12]);

        let rest = w.subset(&[1]);
        let back = sub.merge(&rest).unwrap();
        assert_eq!(back.num_scenarios(), 3);
        assert_eq!(back.upper_bounds(), w.upper_bounds());
        // Merging overlapping arg sets folds rather than duplicating.
        let folded = w.merge(&w.subset(&[0])).unwrap_err();
        assert!(matches!(folded, WorkloadError::DuplicateName { .. }));
        let renamed = Workload::new(vec![Scenario {
            name: "again".into(),
            weight: 1.0,
            trace: w.scenarios()[0].trace.clone(),
        }])
        .unwrap();
        let m = w.merge(&renamed).unwrap();
        assert_eq!(m.num_scenarios(), 3, "identical args fold on merge");
        assert_eq!(m.scenarios()[0].weight, 2.0);
    }

    #[test]
    fn json_roundtrip_preserves_scenarios() {
        let w = fig2_workload(&[4, 9]);
        let j = w.to_json();
        let w2 = Workload::from_json(&Json::parse(&j.to_string_compact()).unwrap()).unwrap();
        assert_eq!(w2.num_scenarios(), 2);
        assert_eq!(w2.design_name(), w.design_name());
        assert_eq!(w2.upper_bounds(), w.upper_bounds());
        for (a, b) in w.scenarios().iter().zip(w2.scenarios()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.weight, b.weight);
            assert_eq!(a.trace.args, b.trace.args);
            assert_eq!(a.trace.total_ops(), b.trace.total_ops());
        }
    }
}
