//! Atomic file writes — the durability primitive under every artifact
//! the toolchain persists (run records, sweep manifests, workload JSON,
//! CSV tables).
//!
//! A crash mid-`fs::write` leaves a truncated file that a later
//! `--resume` would try to parse; [`atomic_write`] closes that window by
//! writing to a sibling temp file, syncing it to disk, `rename`ing
//! onto the destination, and fsyncing the parent directory so the
//! rename itself is durable. On POSIX filesystems the rename is atomic,
//! so readers observe either the old bytes or the new bytes — never a
//! prefix — and after a successful return the *new* bytes survive a
//! power loss (without the directory fsync, a crash right after
//! "success" could still roll the directory entry back to the old
//! file).

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic suffix so concurrent writers targeting the same path (e.g.
/// two sweep cell workers checkpointing one manifest) never share a temp
/// file.
static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Write `contents` to `path` atomically: parent directories are
/// created, the bytes land in a same-directory temp file (so the final
/// `rename` cannot cross filesystems), the temp file is fsynced, the
/// rename publishes it, and the parent directory is fsynced so the
/// rename survives a crash. The temp file is removed on any failure.
pub fn atomic_write(path: &str, contents: &str) -> io::Result<()> {
    let target = Path::new(path);
    if let Some(dir) = target.parent() {
        if !dir.as_os_str().is_empty() {
            fs::create_dir_all(dir)?;
        }
    }
    let tmp = format!(
        "{path}.tmp.{}.{}",
        std::process::id(),
        TEMP_SEQ.fetch_add(1, Ordering::Relaxed)
    );
    let result = (|| {
        let mut f = File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        f.sync_all()?;
        fs::rename(&tmp, target)?;
        sync_parent_dir(target)
    })();
    if result.is_err() {
        let _ = fs::remove_file(&tmp);
    }
    result
}

/// Fsync the directory holding `target` so a just-completed rename is
/// durable, not merely visible. Directory handles can be opened and
/// fsynced on POSIX; on platforms where opening a directory read-only
/// fails (e.g. Windows), the open error is tolerated — there is no
/// portable directory-sync primitive there, and the write itself has
/// already been synced.
fn sync_parent_dir(target: &Path) -> io::Result<()> {
    let dir = match target.parent() {
        Some(d) if !d.as_os_str().is_empty() => d,
        _ => Path::new("."),
    };
    match File::open(dir) {
        Ok(f) => f.sync_all(),
        Err(_) if !cfg!(unix) => Ok(()),
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tempdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("fifoadvisor_fs_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_and_overwrites() {
        let dir = tempdir("basic");
        let path = dir.join("nested/deeper/out.json");
        let path = path.to_str().unwrap();
        atomic_write(path, "first").unwrap();
        assert_eq!(fs::read_to_string(path).unwrap(), "first");
        atomic_write(path, "second").unwrap();
        assert_eq!(fs::read_to_string(path).unwrap(), "second");
        // No temp litter once the write has landed.
        let parent = Path::new(path).parent().unwrap();
        let leftovers: Vec<_> = fs::read_dir(parent)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n.contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn failure_cleans_up_temp_file() {
        let dir = tempdir("fail");
        // Renaming onto a path whose parent is a *file* must fail.
        let blocker = dir.join("blocker");
        fs::write(&blocker, "x").unwrap();
        let target = blocker.join("child.json");
        assert!(atomic_write(target.to_str().unwrap(), "data").is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn parent_dir_sync_covers_all_path_shapes() {
        // The directory fsync must handle explicit parents, bare
        // filenames (parent = cwd), and deep fresh trees alike — and
        // the written bytes must be intact in every case.
        let dir = tempdir("dirsync");
        let nested = dir.join("a/b/c/out.json");
        atomic_write(nested.to_str().unwrap(), "nested").unwrap();
        assert_eq!(fs::read_to_string(&nested).unwrap(), "nested");
        let flat = dir.join("flat.json");
        atomic_write(flat.to_str().unwrap(), "flat").unwrap();
        assert_eq!(fs::read_to_string(&flat).unwrap(), "flat");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sync_parent_dir_resolves_the_containing_directory() {
        let dir = tempdir("dirsync_unit");
        let target = dir.join("x.json");
        fs::write(&target, "x").unwrap();
        sync_parent_dir(&target).unwrap();
        // A target whose parent is missing fails on unix (nothing to
        // make durable) instead of pretending it synced.
        if cfg!(unix) {
            assert!(sync_parent_dir(&dir.join("gone/x.json")).is_err());
        }
        let _ = fs::remove_dir_all(&dir);
    }
}
