//! A minimal JSON value type with a writer and a recursive-descent parser.
//!
//! Used for experiment result files, run configuration, and convergence
//! logs (the offline crate mirror has no `serde`/`serde_json`). Supports
//! the full JSON grammar except `\u` surrogate pairs are passed through
//! unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is
/// deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object constructor from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of numbers.
    pub fn nums<T: Into<f64> + Copy>(values: &[T]) -> Json {
        Json::Arr(values.iter().map(|&v| Json::Num(v.into())).collect())
    }

    /// Array of strings.
    pub fn strs(values: &[&str]) -> Json {
        Json::Arr(values.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = |out: &mut String, n: usize| {
            for _ in 0..n {
                out.push_str("  ");
            }
        };
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    pad(out, indent + 1);
                    item.write_pretty(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    pad(out, indent + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push('}');
            }
            _ => self.write(out),
        }
    }

    /// Parse a JSON document. Errors carry a byte offset.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        fmt::Write::write_fmt(out, format_args!("{}", n as i64)).unwrap();
    } else if n.is_finite() {
        fmt::Write::write_fmt(out, format_args!("{}", n)).unwrap();
    } else {
        // JSON has no Inf/NaN; emit null like most lenient writers.
        out.push_str("null");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32)).unwrap()
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("name", Json::Str("gemm".into())),
            ("fifos", Json::Num(88.0)),
            ("depths", Json::nums(&[2.0, 1024.0, 16.0])),
            (
                "nested",
                Json::obj(vec![("ok", Json::Bool(true)), ("x", Json::Null)]),
            ),
        ]);
        let compact = v.to_string_compact();
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let text = v.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), v);
        assert_eq!(
            Json::parse("\"\\u0041\\n\"").unwrap(),
            Json::Str("A\n".into())
        );
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"a": [1, 2], "b": "x", "c": true, "n": 7}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("n").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("missing"), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn numbers_with_exponents() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-1").unwrap().as_f64(), Some(-0.25));
    }

    #[test]
    fn nonfinite_writes_null() {
        assert_eq!(Json::Num(f64::INFINITY).to_string_compact(), "null");
    }
}
