//! Small self-contained utilities: PRNG, statistics, JSON, atomic file
//! writes, property-test driver. The offline crate mirror ships neither
//! `rand`, `serde`, nor `proptest`, so these are hand-rolled (and
//! unit-tested) here.

pub mod fs;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

pub use fs::atomic_write;
pub use json::Json;
pub use rng::Rng;

/// FNV-1a 64-bit — stable across Rust versions and machines (unlike
/// `DefaultHasher`), so hashes can name content in artifacts shared
/// between processes: sweep cell ids in manifests, store cache keys and
/// payload checksums.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(super::fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(super::fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(super::fnv1a(b"foobar"), 0x85944171f73967e8);
    }
}
