//! Small self-contained utilities: PRNG, statistics, JSON, atomic file
//! writes, property-test driver. The offline crate mirror ships neither
//! `rand`, `serde`, nor `proptest`, so these are hand-rolled (and
//! unit-tested) here.

pub mod fs;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

pub use fs::atomic_write;
pub use json::Json;
pub use rng::Rng;
