//! A miniature property-test driver (the offline mirror lacks `proptest`).
//!
//! `check(name, cases, f)` runs `f` against `cases` independently seeded
//! [`Rng`]s and reports the first failing seed so failures are
//! reproducible with `check_seed`.

use super::rng::Rng;

/// Run `f` for `cases` random cases. Each case gets a deterministic,
/// per-case-seeded RNG. `f` returns `Err(msg)` to fail the property.
///
/// Panics with the failing seed on the first failure.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xF1F0_AD71_0000_0000 ^ case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with util::prop::check_seed({seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed (for debugging).
pub fn check_seed<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    f(&mut rng).expect("property failed on explicit seed");
}

/// Assert-style helper for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 25, |rng| {
            count += 1;
            let v = rng.below(10);
            if v < 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn check_seed_reproduces_stream() {
        let mut first = None;
        check_seed(0x1234, |rng| {
            first = Some(rng.next_u64());
            Ok(())
        });
        let mut second = None;
        check_seed(0x1234, |rng| {
            second = Some(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
