//! A miniature property-test driver (the offline mirror lacks `proptest`)
//! plus the **shared generator set** every randomized suite draws from.
//!
//! `check(name, cases, f)` runs `f` against `cases` independently seeded
//! [`Rng`]s and reports the first failing seed so failures are
//! reproducible with `check_seed`. When `FIFOADVISOR_FUZZ_ARTIFACT_DIR`
//! is set, failing seeds are additionally appended to
//! `failing_seeds.jsonl` in that directory before the panic — the CI fuzz
//! job uploads it as an artifact.
//!
//! The generators (random depth vectors, DSE-shaped depth mutations,
//! random layered designs, the deadlock-boundary and pair-burst fixture
//! designs, random multi-scenario workloads) used to be duplicated across
//! `tests/incremental_fuzz.rs`, `tests/pruning_fuzz.rs` and
//! `tests/workload_equivalence.rs`; they live here so every differential
//! suite — including `tests/backend_conformance.rs` — explores the same
//! seeded corpus. [`iters`] reads `FIFOADVISOR_FUZZ_ITERS` so the CI fuzz
//! job can crank case counts without code changes.

use super::rng::Rng;
use crate::ir::{Design, DesignBuilder, Expr};
use crate::trace::workload::Workload;

/// Iteration count for randomized suites: the `FIFOADVISOR_FUZZ_ITERS`
/// environment value when set (the CI fuzz job cranks it up in release
/// mode), otherwise `default`.
pub fn iters(default: u64) -> u64 {
    std::env::var("FIFOADVISOR_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Append a failing property seed to `$FIFOADVISOR_FUZZ_ARTIFACT_DIR/
/// failing_seeds.jsonl` (best-effort; errors are ignored so the panic
/// with the seed always happens).
fn dump_failing_seed(name: &str, case: u64, seed: u64) {
    let Ok(dir) = std::env::var("FIFOADVISOR_FUZZ_ARTIFACT_DIR") else {
        return;
    };
    if dir.is_empty() {
        return;
    }
    let _ = std::fs::create_dir_all(&dir);
    let path = std::path::Path::new(&dir).join("failing_seeds.jsonl");
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
    {
        use std::io::Write;
        let _ = writeln!(
            f,
            "{{\"property\": \"{name}\", \"case\": {case}, \"seed\": \"{seed:#x}\"}}"
        );
    }
}

/// Run `f` for `cases` random cases. Each case gets a deterministic,
/// per-case-seeded RNG. `f` returns `Err(msg)` to fail the property.
///
/// Panics with the failing seed on the first failure.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xF1F0_AD71_0000_0000 ^ case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            dump_failing_seed(name, case, seed);
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with util::prop::check_seed({seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed (for debugging).
pub fn check_seed<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    f(&mut rng).expect("property failed on explicit seed");
}

/// Assert-style helper for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

// ---------------------------------------------------------------------------
// Shared generators
// ---------------------------------------------------------------------------

/// Every suite design name plus the data-dependent specials (`fig2`,
/// `flowgnn_pna`) — the canonical iteration set of the differential
/// suites.
pub fn suite_with_specials() -> Vec<&'static str> {
    let mut v = crate::bench_suite::all_names();
    v.extend(["fig2", "flowgnn_pna"]);
    v
}

/// A DSE-shaped random depth vector in `[1, ub + pad]` per channel —
/// `pad` pushes past the bounds so the occupancy-clamp region above the
/// observed write counts is reachable even on unhinted designs.
pub fn random_depths(rng: &mut Rng, ub: &[u32], pad: u32) -> Vec<u32> {
    ub.iter()
        .map(|&u| rng.range_u32(1, u.max(2) + pad))
        .collect()
}

/// One DSE-shaped fuzz step: mutate 1–2 channels (occasionally
/// re-randomize the whole vector). Mutations are biased toward corners
/// and near-boundary values: SRL thresholds, the Vitis minimum, ±1 steps
/// (the SA move shape), and uniform draws.
pub fn mutate_depths(rng: &mut Rng, cfg: &mut [u32], ub: &[u32]) {
    let full = rng.chance(0.05);
    if full {
        for (d, &u) in cfg.iter_mut().zip(ub) {
            *d = rng.range_u32(1, u.max(2) + 2);
        }
        return;
    }
    let n_mut = if rng.chance(0.7) { 1 } else { 2 };
    for _ in 0..n_mut {
        let i = rng.index(cfg.len());
        let u = ub[i].max(2);
        cfg[i] = match rng.below(5) {
            0 => 1,
            1 => 2,
            2 => u,
            3 => {
                if rng.chance(0.5) {
                    (cfg[i] + 1).min(u + 2)
                } else {
                    cfg[i].saturating_sub(1).max(1)
                }
            }
            _ => rng.range_u32(1, u + 2),
        };
    }
}

/// Bursty producers + an alternating pair-read consumer (the matmul PE
/// access pattern): exercises the homogeneous-run and pair-burst fast
/// paths. Channel `c` is wide (512 bits), so small depth changes flip
/// SRL↔BRAM.
pub fn pair_burst_design(n: u64) -> Design {
    let mut b = DesignBuilder::new("pairburst", 0);
    let a = b.channel("a", 32);
    let c = b.channel("c", 512);
    let s = b.channel("s", 32);
    b.process("pa", move |p| {
        p.for_n(n, |p, _| p.write(a, Expr::c(0)));
    });
    b.process("pc", move |p| {
        p.for_n(n, |p, _| p.write(c, Expr::c(0)));
    });
    b.process("pe", move |p| {
        p.for_n(n, |p, _| {
            let _ = p.read(a);
            let _ = p.read(c);
        });
        p.for_n(n, |p, _| p.write(s, Expr::c(0)));
    });
    b.process("sink", move |p| {
        p.for_n(n, |p, _| {
            let _ = p.read(s);
        });
    });
    b.build()
}

/// Fig. 2-shaped design (one `n` kernel argument): feasibility flips as
/// depth(x) crosses `n − 1`, so mutation chains repeatedly cross the
/// deadlock boundary. Channel `y` is wide for SRL↔BRAM coverage.
pub fn deadlock_boundary_design() -> Design {
    let mut b = DesignBuilder::new("boundary", 1);
    let x = b.channel("x", 32);
    let y = b.channel("y", 256);
    b.process("producer", |p| {
        p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
        p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
    });
    b.process("consumer", |p| {
        p.for_expr(Expr::arg(0), |p, _| {
            let _ = p.read(x);
            let _ = p.read(y);
        });
    });
    b.build()
}

/// Random layered DAG: 2–4 stages of fan-out channels with random widths
/// (wide ones for SRL↔BRAM flips), token counts and delays biased toward
/// zero so homogeneous bursts form.
pub fn random_layered_design(rng: &mut Rng) -> Design {
    let n_stages = 2 + rng.index(3);
    let mut b = DesignBuilder::new("rand", 0);
    let mut prev: Option<(Vec<usize>, u64)> = None;
    for s in 0..n_stages {
        let width = *rng.choose(&[8u32, 32, 64, 512]);
        let fanout = 1 + rng.index(3);
        let tokens = 1 + rng.below(20);
        let chans: Vec<usize> = (0..fanout)
            .map(|i| b.channel(&format!("c{s}_{i}"), width))
            .collect();
        let delay_in = if rng.chance(0.6) { 0 } else { rng.below(3) as u32 };
        let delay_out = if rng.chance(0.6) { 0 } else { rng.below(3) as u32 };
        match prev.clone() {
            None => {
                let cc = chans.clone();
                b.process(&format!("src{s}"), move |p| {
                    p.for_n(tokens, |p, _| {
                        for &c in &cc {
                            p.delay(delay_out);
                            p.write(c, Expr::c(1));
                        }
                    });
                });
            }
            Some((inputs, in_tokens)) => {
                let cc = chans.clone();
                let ins = inputs.clone();
                b.process(&format!("stage{s}"), move |p| {
                    p.for_n(in_tokens, |p, _| {
                        for &c in &ins {
                            p.delay(delay_in);
                            let _ = p.read(c);
                        }
                    });
                    p.for_n(tokens, |p, _| {
                        for &c in &cc {
                            p.delay(delay_out);
                            p.write(c, Expr::c(1));
                        }
                    });
                });
            }
        }
        prev = Some((chans, tokens));
    }
    let (inputs, in_tokens) = prev.unwrap();
    b.process("sink", move |p| {
        p.for_n(in_tokens, |p, _| {
            for &c in &inputs {
                let _ = p.read(c);
            }
        });
    });
    b.build()
}

/// The lane-count grid the batched-backend conformance suites sweep:
/// K = 1 (degenerate single lane), small odd, a mid batch, and a wide
/// one that crosses typical optimizer batch widths.
pub const LANE_GRID: [usize; 4] = [1, 3, 8, 64];

/// A DSE-shaped batch of `k` depth vectors for lane-batched evaluation:
/// generated as a mutation chain (each lane is a 1–2 channel mutation of
/// the previous, the SA/NSGA-II proposal shape) with ~15% of lanes
/// duplicating an earlier lane exactly — duplicate configurations in one
/// batch are legal and must produce identical per-lane outcomes.
pub fn random_lane_batch(rng: &mut Rng, ub: &[u32], k: usize) -> Vec<Box<[u32]>> {
    let mut batch: Vec<Box<[u32]>> = Vec::with_capacity(k);
    let mut cur = random_depths(rng, ub, 2);
    for _ in 0..k {
        if !batch.is_empty() && rng.chance(0.15) {
            let i = rng.index(batch.len());
            batch.push(batch[i].clone());
            continue;
        }
        batch.push(cur.clone().into_boxed_slice());
        mutate_depths(rng, &mut cur, ub);
    }
    batch
}

/// A random multi-scenario workload over the deadlock-boundary design:
/// 2–4 scenarios with distinct `n` arguments, so per-scenario deadlock
/// thresholds differ and the worst-case aggregation, the any-scenario
/// infeasibility rule and the early-exit probe ordering all engage.
pub fn random_workload(rng: &mut Rng) -> Workload {
    let design = deadlock_boundary_design();
    let k = 2 + rng.index(3);
    let mut ns: Vec<i64> = Vec::new();
    while ns.len() < k {
        let n = 2 + rng.below(24) as i64;
        if !ns.contains(&n) {
            ns.push(n);
        }
    }
    let sets: Vec<Vec<i64>> = ns.into_iter().map(|n| vec![n]).collect();
    Workload::from_design_args(&design, &sets).expect("boundary workload must build")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 25, |rng| {
            count += 1;
            let v = rng.below(10);
            if v < 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn check_seed_reproduces_stream() {
        let mut first = None;
        check_seed(0x1234, |rng| {
            first = Some(rng.next_u64());
            Ok(())
        });
        let mut second = None;
        check_seed(0x1234, |rng| {
            second = Some(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn iters_defaults_without_env() {
        // The fuzz env var is unset in unit-test runs; the default flows
        // through. (The cranked path is exercised by the CI fuzz job.)
        if std::env::var("FIFOADVISOR_FUZZ_ITERS").is_err() {
            assert_eq!(iters(17), 17);
        }
    }

    #[test]
    fn generators_produce_valid_designs_and_workloads() {
        let mut rng = Rng::new(0xD5E);
        let d = random_layered_design(&mut rng);
        let t = crate::trace::collect_trace(&d, &[]).expect("layered design must trace");
        assert!(t.total_ops() > 0);
        let ub = t.upper_bounds();
        let mut cfg = random_depths(&mut rng, &ub, 5);
        assert_eq!(cfg.len(), ub.len());
        assert!(cfg.iter().all(|&d| d >= 1));
        for _ in 0..20 {
            mutate_depths(&mut rng, &mut cfg, &ub);
            assert!(cfg.iter().all(|&d| d >= 1));
        }
        let w = random_workload(&mut rng);
        assert!(w.num_scenarios() >= 2);
        let names = suite_with_specials();
        assert!(names.contains(&"fig2") && names.contains(&"flowgnn_pna"));
        assert!(names.len() >= 24);
        for &k in &LANE_GRID {
            let batch = random_lane_batch(&mut rng, &ub, k);
            assert_eq!(batch.len(), k);
            assert!(batch
                .iter()
                .all(|c| c.len() == ub.len() && c.iter().all(|&d| d >= 1)));
        }
    }
}
