//! Deterministic pseudo-random number generation.
//!
//! `splitmix64` state update with a `xorshift`-style output mix — the same
//! construction used to seed xoshiro generators. Statistically strong
//! enough for stochastic optimizers and property tests, fully
//! reproducible, and dependency-free.

/// A small, fast, seedable PRNG (SplitMix64).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a 64-bit seed. Two generators with the same
    /// seed produce identical streams.
    pub fn new(seed: u64) -> Self {
        Rng {
            // Avoid the all-zero fixed point of trivial mixes.
            state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15),
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform value in `[0, n)`. `n` must be nonzero.
    ///
    /// Uses Lemire's multiply-shift rejection method for unbiased bounded
    /// generation.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as u32
    }

    /// Uniform float in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Choose a uniformly random element of a nonempty slice.
    #[inline]
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.index(items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = r.range_u32(5, 8);
            assert!((5..=8).contains(&v));
            lo_seen |= v == 5;
            hi_seen |= v == 8;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_reasonable() {
        let mut r = Rng::new(11);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(13);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
