//! Statistics helpers used by the experiment harnesses: geometric means
//! (the paper reports geomean latency ratios and speedups), percentiles,
//! and simple summaries.

/// Geometric mean of strictly-positive values. Returns `None` for an empty
/// slice or any non-positive value.
pub fn geomean(values: &[f64]) -> Option<f64> {
    if values.is_empty() || values.iter().any(|&v| v <= 0.0 || !v.is_finite()) {
        return None;
    }
    let log_sum: f64 = values.iter().map(|v| v.ln()).sum();
    Some((log_sum / values.len() as f64).exp())
}

/// Arithmetic mean. Returns `None` for an empty slice.
pub fn mean(values: &[f64]) -> Option<f64> {
    if values.is_empty() {
        return None;
    }
    Some(values.iter().sum::<f64>() / values.len() as f64)
}

/// Sample standard deviation (n-1 denominator). `None` for n < 2.
pub fn stddev(values: &[f64]) -> Option<f64> {
    if values.len() < 2 {
        return None;
    }
    let m = mean(values)?;
    let var = values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (values.len() - 1) as f64;
    Some(var.sqrt())
}

/// Percentile by linear interpolation on the sorted data, `p` in `[0, 100]`.
pub fn percentile(values: &[f64], p: f64) -> Option<f64> {
    if values.is_empty() || !(0.0..=100.0).contains(&p) {
        return None;
    }
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    Some(sorted[lo] * (1.0 - frac) + sorted[hi] * frac)
}

/// Median (50th percentile).
pub fn median(values: &[f64]) -> Option<f64> {
    percentile(values, 50.0)
}

/// Compact summary of a sample, used by the bench harness.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub median: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize a nonempty sample. Panics on empty input.
    pub fn of(values: &[f64]) -> Summary {
        assert!(!values.is_empty(), "Summary::of on empty sample");
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: values.len(),
            mean: mean(values).unwrap(),
            stddev: stddev(values).unwrap_or(0.0),
            min: sorted[0],
            median: median(values).unwrap(),
            p95: percentile(values, 95.0).unwrap(),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Format a duration in seconds with an adaptive unit (ns/µs/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2} s", secs)
    } else if secs < 7200.0 {
        format!("{:.1} min", secs / 60.0)
    } else if secs < 2.0 * 86400.0 {
        format!("{:.1} h", secs / 3600.0)
    } else {
        format!("{:.2} days", secs / 86400.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert_eq!(geomean(&[]), None);
        assert_eq!(geomean(&[2.0, 0.0]), None);
        let g = geomean(&[1.0, 4.0]).unwrap();
        assert!((g - 2.0).abs() < 1e-12);
        let g = geomean(&[2.0, 2.0, 2.0]).unwrap();
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_ratios_matches_paper_style() {
        // speedup geomean like Table III: 10^6.53 etc.
        let speedups = [1e6, 1e7, 3.2e6];
        let g = geomean(&speedups).unwrap();
        assert!(g >= 1e6 && g <= 1e7);
    }

    #[test]
    fn mean_stddev() {
        assert_eq!(mean(&[]), None);
        assert_eq!(mean(&[1.0, 3.0]), Some(2.0));
        assert_eq!(stddev(&[1.0]), None);
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]).unwrap();
        assert!((s - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), Some(1.0));
        assert_eq!(percentile(&v, 100.0), Some(4.0));
        assert_eq!(median(&v), Some(2.5));
        assert_eq!(percentile(&v, 101.0), None);
    }

    #[test]
    fn summary_fields_consistent() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(0.5e-9 * 2.0), "1.0 ns");
        assert!(fmt_duration(2.5e-6).contains("µs"));
        assert!(fmt_duration(1.5e-3).contains("ms"));
        assert!(fmt_duration(3.0).contains("s"));
        assert!(fmt_duration(300.0).contains("min"));
        assert!(fmt_duration(3.0 * 86400.0).contains("days"));
    }
}
