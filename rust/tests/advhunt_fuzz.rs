//! Differential fuzzing of the adversarial outer loop (`dse::advhunt`):
//! randomized workloads and hunt configurations asserting that
//!
//! - **distillation is invisible** — a distilled run's merged history
//!   and Pareto front are bit-identical to a from-scratch full-bank run
//!   with the same optimizer and seed, across stats-driven and
//!   stats-free optimizers, on random multi-scenario workloads,
//! - **certificates are sound on the boundary** — for any sub-floor
//!   Fig. 2 config the exhaustive `auto` hunt finds a concrete breaking
//!   argument vector at or above the paper's `n − 1` threshold, and
//! - **hunts are deterministic** — re-running a hunt with the same seed,
//!   or with a parallel worker pool, reproduces the same counterexample,
//!   scenario count, simulation count, and best-pressure scenario.
//!
//! Plus the FlowGNN-PNA acceptance smoke: a config sized to a single
//! graph's write counts is broken by a sibling graph in the argument
//! space, while the all-graphs workload's Baseline-Max certifies
//! clean-exhaustive.
//!
//! Cases run under `util::prop::check`, so a failure reports its seed
//! (and the CI fuzz job cranks counts via `FIFOADVISOR_FUZZ_ITERS` and
//! uploads failing seeds through `FIFOADVISOR_FUZZ_ARTIFACT_DIR`).

use fifoadvisor::bench_suite::{self, flowgnn};
use fifoadvisor::dse::advhunt::{certify, certify_design, hunt, DistillConfig, HuntConfig};
use fifoadvisor::dse::{drive, optimize_distilled, CancelToken, EvalEngine};
use fifoadvisor::opt::{by_name, Space};
use fifoadvisor::trace::workload::Workload;
use fifoadvisor::util::prop::{check, iters, random_workload};
use std::sync::Arc;

/// History/front rows projected to the fields the bit-identity claim is
/// about (timestamps are wall-clock and excluded).
fn rows(pts: &[fifoadvisor::dse::EvalPoint]) -> Vec<(Box<[u32]>, Option<u64>, u32)> {
    pts.iter()
        .map(|p| (p.depths.clone(), p.latency, p.bram))
        .collect()
}

#[test]
fn distilled_runs_match_full_bank_on_random_workloads() {
    // Rotate through stats-free optimizers AND a stats-driven one
    // (greedy), which exercises the full-engine wants_stats path of the
    // split drive loop.
    let optimizers = ["sa", "grouped_sa", "nsga2", "grouped_random", "greedy"];
    check("distill ≡ full bank at fixpoint", iters(10), |rng| {
        let w = Arc::new(random_workload(rng));
        let space = Space::from_workload(&w);
        let optimizer = optimizers[rng.index(optimizers.len())].to_string();
        let seed = rng.below(1_000);
        let budget = 30 + rng.below(30) as usize;
        let cfg = DistillConfig {
            optimizer: optimizer.clone(),
            seed,
            budget,
            ..DistillConfig::default()
        };
        let out = optimize_distilled(&w, &space, &cfg);
        if out.truncated {
            return Err("no budgets configured, nothing may truncate".into());
        }
        // Fixpoint bookkeeping invariants.
        if out.iterations < 1 || out.kept_final.is_empty() {
            return Err(format!(
                "degenerate fixpoint: {} iterations, kept {:?}",
                out.iterations, out.kept_final
            ));
        }
        for p in &out.promotions {
            if out.kept_initial.contains(p) || !out.kept_final.contains(p) {
                return Err(format!(
                    "promotion {p} inconsistent with kept {:?} → {:?}",
                    out.kept_initial, out.kept_final
                ));
            }
        }

        // Reference: a from-scratch full-bank run, same optimizer + seed
        // (the engine configuration optimize_distilled defaults to).
        let mut full = EvalEngine::for_workload(w.clone(), 1);
        full.eval_baselines();
        let mut opt = by_name(&optimizer, seed)
            .ok_or_else(|| format!("unknown optimizer {optimizer}"))?;
        drive(&mut *opt, &mut full, &space, budget);
        if rows(&out.history) != rows(&full.history) {
            return Err(format!(
                "{optimizer} seed {seed}: distilled history diverged \
                 (kept {:?}, promoted {:?})",
                out.kept_final, out.promotions
            ));
        }
        let ref_front: Vec<_> = full.pareto().into_iter().cloned().collect();
        if rows(&out.front) != rows(&ref_front) {
            return Err(format!("{optimizer} seed {seed}: front diverged"));
        }
        Ok(())
    });
}

#[test]
fn certify_below_the_floor_always_finds_a_counterexample() {
    // Fig. 2: a depth-d x channel survives n ≤ d + 1 and deadlocks for
    // n ≥ d + 2; the space reaches n = 32, so every d ≤ 30 is broken
    // and the exhaustive auto hunt (31 points ≤ 64 budget) must say so.
    check("sub-floor certificates find the break", iters(10), |rng| {
        let d = 2 + rng.below(29) as u32;
        let cert = certify_design("fig2", &[d, 2], &HuntConfig::default()).unwrap();
        let ce = cert
            .counterexample
            .ok_or_else(|| format!("depth {d}: no counterexample in {}", cert.verdict()))?;
        if (ce.args[0] as u32) < d + 2 {
            return Err(format!("depth {d}: n = {} should survive", ce.args[0]));
        }
        if !ce.blocked.contains(&0) {
            return Err(format!("depth {d}: x not in blocked set {:?}", ce.blocked));
        }
        if !cert.verdict().starts_with("broken@") {
            return Err(format!("depth {d}: verdict {}", cert.verdict()));
        }
        Ok(())
    });
}

#[test]
fn hunts_reproduce_across_reruns_and_worker_pools() {
    let designs = ["fig2", "mini_dnn", "flowgnn_pna"];
    let optimizers = ["auto", "random", "sa", "grouped_sa", "nsga2"];
    check("hunt determinism: serial == jobs N", iters(8), |rng| {
        let name = designs[rng.index(designs.len())];
        let bd = bench_suite::build(name);
        let space = bench_suite::arg_space(name).unwrap();
        let cfg = HuntConfig {
            optimizer: optimizers[rng.index(optimizers.len())].to_string(),
            seed: rng.below(1_000),
            budget: 8 + rng.below(24) as usize,
            ..HuntConfig::default()
        };
        // Half the cases hunt in break mode against a sub-maximum fig2
        // config; the rest mine pressure (depths = None works on any
        // design without knowing its FIFO count).
        let depths: Option<Vec<u32>> = if name == "fig2" && rng.chance(0.5) {
            Some(vec![2 + rng.below(29) as u32, 2])
        } else {
            None
        };
        let a = hunt(&bd.design, &space, depths.as_deref(), &cfg);
        let b = hunt(&bd.design, &space, depths.as_deref(), &cfg);
        let par = hunt(
            &bd.design,
            &space,
            depths.as_deref(),
            &HuntConfig {
                jobs: 2 + rng.index(3),
                ..cfg.clone()
            },
        );
        for (tag, r) in [("rerun", &b), ("parallel", &par)] {
            if r.counterexample != a.counterexample
                || r.scenarios_tested != a.scenarios_tested
                || r.sims != a.sims
                || r.floor_hits != a.floor_hits
                || r.best != a.best
            {
                return Err(format!(
                    "{name}/{} seed {}: {tag} hunt diverged \
                     ({:?} vs {:?}, {} vs {} scenarios)",
                    cfg.optimizer,
                    cfg.seed,
                    r.counterexample,
                    a.counterexample,
                    r.scenarios_tested,
                    a.scenarios_tested,
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn cancelled_hunts_report_truncation_not_verdicts() {
    check("zero-budget hunts truncate cleanly", iters(6), |rng| {
        let bd = bench_suite::build("fig2");
        let space = bench_suite::arg_space("fig2").unwrap();
        let cfg = HuntConfig {
            optimizer: "random".to_string(),
            seed: rng.below(1_000),
            budget: 1_000,
            cancel: CancelToken::with_limits(None, Some(0)),
            ..HuntConfig::default()
        };
        let r = hunt(&bd.design, &space, Some(&[31, 2]), &cfg);
        if !r.truncated {
            return Err("sim budget 0 must truncate".into());
        }
        let cert = certify(&bd.design, "fig2", &space, &[31, 2], &cfg);
        if cert.is_exhaustive() {
            return Err("a truncated clean certificate is never exhaustive".into());
        }
        if !cert.verdict().starts_with("clean?") {
            return Err(format!("verdict {}", cert.verdict()));
        }
        Ok(())
    });
}

/// §IV-D acceptance smoke: sizing FIFOs against one graph's trace is
/// exactly the trap the certificate exists to catch.
#[test]
fn flowgnn_graph0_config_breaks_but_workload_config_certifies_clean() {
    let bd = bench_suite::build("flowgnn_pna");
    let space = bench_suite::arg_space("flowgnn_pna").unwrap();
    // A config sized to graph 0's exact per-channel write counts:
    // feasible on graph 0 (no channel can fill), broken by a sibling
    // graph whose bursts exceed them.
    let w = Arc::new(bench_suite::build_workload("flowgnn_pna").unwrap());
    let s0 = &w.scenarios()[0].trace;
    let mut cfg0 = s0.baseline_min();
    for (l, c) in s0.channels.iter().enumerate() {
        cfg0[l] = (c.writes as u32).max(2);
    }
    let broken = certify(&bd.design, "flowgnn_pna", &space, &cfg0, &HuntConfig::default());
    let ce = broken
        .counterexample
        .expect("a sibling graph must deadlock the graph-0-sized config");
    assert!(flowgnn::SCENARIO_SEEDS.contains(&ce.args[2]));
    assert_ne!(
        ce.args[2],
        flowgnn::SCENARIO_SEEDS[0],
        "graph 0 itself runs this config"
    );

    // The workload-optimized config — Baseline-Max over ALL the graphs
    // the argument space can produce — certifies clean over the entire
    // space (8 points ≤ 64 budget → exhaustive, so the verdict is exact).
    let w8 = Workload::from_design(
        &bd.design,
        &flowgnn::scenario_args(flowgnn::SCENARIO_SEEDS.len()),
    )
    .unwrap();
    let clean = certify(
        &bd.design,
        "flowgnn_pna",
        &space,
        &w8.baseline_max(),
        &HuntConfig::default(),
    );
    assert!(clean.is_exhaustive(), "verdict {}", clean.verdict());
    assert_eq!(clean.scenarios_tested, flowgnn::SCENARIO_SEEDS.len());
}
