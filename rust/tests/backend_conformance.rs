//! The unified cross-backend conformance harness.
//!
//! Every simulation backend must be pinned to the same semantics, at
//! every level of the verification pyramid:
//!
//! 1. **golden** — the cycle-stepped reference defines the semantics;
//! 2. **fast** (`FastSim`) and **compiled** (`CompiledSim`) must be
//!    **bit-identical to each other** (full [`SimOutcome`]s: latency,
//!    deadlock verdict, *and* blocked sets) and latency-exact against
//!    golden, warm (incremental) and cold alike;
//! 3. **batched** (`BatchedSim`) must be bit-identical **per lane** to
//!    the single-config backends for every lane of every batch shape —
//!    the `util::prop::LANE_GRID` K values, ragged final batches,
//!    duplicate configurations in one batch, and mixed per-lane deadlock
//!    verdicts (blocked sets included);
//! 4. **bank** — `ScenarioSim` over any backend must agree on aggregate
//!    verdicts, per-scenario latencies and merged stats, including the
//!    lane-batched `eval_batch` bank path;
//! 5. **engine** — `EvalEngine` histories and Pareto fronts must be
//!    identical for every optimizer under `--backend compiled` and
//!    `--backend batched`, serial and `--jobs 4`, pruning on and off,
//!    and the analytic-bounds telemetry (`bounds_floor_hits`,
//!    `cap_tightenings`) must be invariant across jobs and backends.
//!
//! All randomness comes from the shared `util::prop` generator set, so
//! this suite explores the same seeded corpus as the incremental and
//! pruning fuzzers; `FIFOADVISOR_FUZZ_ITERS` cranks the case counts (the
//! CI fuzz job runs it in release mode).

use fifoadvisor::bench_suite;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::{self, Space};
use fifoadvisor::sim::batched::BatchedSim;
use fifoadvisor::sim::compiled::CompiledSim;
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::golden::simulate_golden;
use fifoadvisor::sim::{BackendKind, ScenarioSim, SimOptions};
use fifoadvisor::trace::collect_trace;
use fifoadvisor::trace::Trace;
use fifoadvisor::util::prop::{
    self, deadlock_boundary_design, mutate_depths, pair_burst_design, random_depths,
    random_lane_batch, random_layered_design, random_workload, suite_with_specials, LANE_GRID,
};
use fifoadvisor::util::Rng;
use std::sync::Arc;

/// Golden is cycle-stepped and therefore slow; spot-check it only on
/// traces below this op count (the big Stream-HLS kernels are covered by
/// the fast↔compiled identity plus golden's own per-family tests).
const GOLDEN_OPS_CUTOFF: usize = 8_000;

fn trace_of(name: &str) -> Arc<Trace> {
    let bd = bench_suite::build(name);
    Arc::new(collect_trace(&bd.design, &bd.args).unwrap())
}

/// Walk a mutation chain over one trace, holding the two warm backends
/// (their delta paths), a cold compiled backend (its full path), and —
/// on small traces — the golden reference to the same answers.
fn conformance_walk(t: &Arc<Trace>, rng: &mut Rng, steps: usize, ctx: &str) {
    let mut fast = FastSim::new(t.clone());
    let mut comp = CompiledSim::new(t.clone());
    let mut comp_cold = CompiledSim::new(t.clone());
    comp_cold.set_incremental(false);
    let ub = t.upper_bounds();
    let golden_ok = t.total_ops() <= GOLDEN_OPS_CUTOFF;
    let mut cfg = random_depths(rng, &ub, 3);
    for step in 0..steps {
        let f = fast.simulate(&cfg);
        let c = comp.simulate(&cfg);
        assert_eq!(
            f, c,
            "{ctx} step {step}: compiled (warm) != fast, cfg {cfg:?}"
        );
        let cc = comp_cold.simulate(&cfg);
        assert_eq!(
            c, cc,
            "{ctx} step {step}: compiled warm != compiled cold, cfg {cfg:?}"
        );
        if golden_ok && step % 3 == 0 {
            let g = simulate_golden(t, &cfg, SimOptions::default());
            assert_eq!(
                c.latency(),
                g.latency(),
                "{ctx} step {step}: compiled != golden, cfg {cfg:?}"
            );
        }
        mutate_depths(rng, &mut cfg, &ub);
    }
}

#[test]
fn backends_agree_on_every_suite_design() {
    let steps = prop::iters(6) as usize;
    for name in suite_with_specials() {
        let t = trace_of(name);
        let mut rng = Rng::new(0xC0FF ^ name.len() as u64);
        conformance_walk(&t, &mut rng, steps, name);
    }
}

#[test]
fn backends_agree_across_deadlock_boundaries() {
    // Deterministic sweep straight across the fig2 feasibility threshold
    // (x = n-1), both directions, so each backend's incremental path
    // crosses deadlock↔feasible repeatedly.
    let d = deadlock_boundary_design();
    for n in [5i64, 16] {
        let t = Arc::new(collect_trace(&d, &[n]).unwrap());
        let mut fast = FastSim::new(t.clone());
        let mut comp = CompiledSim::new(t.clone());
        let thresh = (n - 1) as u32;
        let sweep: Vec<u32> = (thresh.saturating_sub(2)..=thresh + 2)
            .chain((thresh.saturating_sub(2)..=thresh + 2).rev())
            .collect();
        for dx in sweep {
            for dy in [2u32, 3] {
                let cfg = [dx.max(1), dy];
                let f = fast.simulate(&cfg);
                let c = comp.simulate(&cfg);
                assert_eq!(f, c, "n={n} cfg {cfg:?}");
                let g = simulate_golden(&t, &cfg, SimOptions::default());
                assert_eq!(c.latency(), g.latency(), "n={n} cfg {cfg:?} vs golden");
            }
        }
    }
}

#[test]
fn backends_agree_across_srl_bram_flips() {
    // Toggle the wide (512-bit) channel across the SRL threshold so the
    // compiled backend's read-edge reweighting invalidation is exercised
    // against fast's read invalidation.
    let d = pair_burst_design(32);
    let t = Arc::new(collect_trace(&d, &[]).unwrap());
    let mut fast = FastSim::new(t.clone());
    let mut comp = CompiledSim::new(t.clone());
    for i in 0..24u32 {
        let c_depth = if i % 2 == 0 { 2 } else { 3 + (i % 3) };
        let cfg = [8u32, c_depth, 8];
        let f = fast.simulate(&cfg);
        let c = comp.simulate(&cfg);
        assert_eq!(f, c, "toggle {i}, cfg {cfg:?}");
        let g = simulate_golden(&t, &cfg, SimOptions::default());
        assert_eq!(c.latency(), g.latency(), "toggle {i} vs golden");
    }
}

#[test]
fn property_backends_agree_on_random_designs() {
    prop::check(
        "compiled == fast == golden on random designs",
        prop::iters(30),
        |rng| {
            let design = random_layered_design(rng);
            let t = Arc::new(collect_trace(&design, &[]).map_err(|e| e.to_string())?);
            let mut fast = FastSim::new(t.clone());
            let mut comp = CompiledSim::new(t.clone());
            let ub = t.upper_bounds();
            let mut cfg: Vec<u32> = random_depths(rng, &ub, 2);
            for step in 0..24 {
                let f = fast.simulate(&cfg);
                let c = comp.simulate(&cfg);
                if f != c {
                    return Err(format!(
                        "step {step}: compiled {c:?} != fast {f:?} at cfg {cfg:?}"
                    ));
                }
                if step % 6 == 0 {
                    let g = simulate_golden(&t, &cfg, SimOptions::default());
                    if c.latency() != g.latency() {
                        return Err(format!(
                            "step {step}: compiled {:?} != golden {:?} at cfg {cfg:?}",
                            c.latency(),
                            g.latency()
                        ));
                    }
                }
                mutate_depths(rng, &mut cfg, &ub);
            }
            Ok(())
        },
    );
}

#[test]
fn property_stats_agree_on_random_designs() {
    // The stats path (occupancy merge + stall post-pass) drives greedy's
    // ranking and the targeted hunter; both backends must produce the
    // same numbers, not just the same outcomes.
    prop::check(
        "compiled stats == fast stats on random designs",
        prop::iters(15),
        |rng| {
            let design = random_layered_design(rng);
            let t = Arc::new(collect_trace(&design, &[]).map_err(|e| e.to_string())?);
            let mut fast = FastSim::new(t.clone());
            let mut comp = CompiledSim::new(t.clone());
            let ub = t.upper_bounds();
            for _ in 0..6 {
                let cfg = random_depths(rng, &ub, 2);
                let (fo, fs) = fast.simulate_with_stats(&cfg);
                let (co, cs) = comp.simulate_with_stats(&cfg);
                prop_check(fo == co, format!("outcome diverged at {cfg:?}"))?;
                prop_check(
                    fs.max_occupancy == cs.max_occupancy,
                    format!("occupancy diverged at {cfg:?}"),
                )?;
                prop_check(
                    fs.write_stall == cs.write_stall && fs.read_stall == cs.read_stall,
                    format!("stalls diverged at {cfg:?}"),
                )?;
            }
            Ok(())
        },
    );
}

fn prop_check(cond: bool, msg: String) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg)
    }
}

#[test]
fn property_random_workload_banks_agree() {
    prop::check(
        "fast bank == compiled bank on random workloads",
        prop::iters(20),
        |rng| {
            let w = random_workload(rng);
            let mut fast_bank = ScenarioSim::new(&w);
            let mut comp_bank =
                ScenarioSim::with_backend(&w, SimOptions::default(), BackendKind::Compiled);
            let mut full = ScenarioSim::new(&w);
            let ub = w.upper_bounds();
            let mut cfg = random_depths(rng, &ub, 2);
            for step in 0..12 {
                let f = fast_bank.simulate(&cfg);
                let c = comp_bank.simulate(&cfg);
                prop_check(
                    f == c,
                    format!("step {step}: bank outcome diverged at {cfg:?}"),
                )?;
                prop_check(
                    fast_bank.scenario_latencies() == comp_bank.scenario_latencies(),
                    format!("step {step}: per-scenario latencies diverged at {cfg:?}"),
                )?;
                // The early-exit probe path agrees with both backends'
                // full-path verdicts regardless of probe history.
                let fast_early = full.eval_latency(&cfg, true);
                prop_check(
                    fast_early == c.latency(),
                    format!("step {step}: early-exit diverged at {cfg:?}"),
                )?;
                mutate_depths(rng, &mut cfg, &ub);
            }
            Ok(())
        },
    );
}

/// Assert one `BatchedSim::eval_batch` against per-config `FastSim`
/// ground truth: full per-lane `SimOutcome` identity (latency, deadlock
/// verdict, blocked sets).
fn assert_lanes_match_fast(
    t: &Arc<Trace>,
    bat: &mut BatchedSim,
    batch: &[Box<[u32]>],
    ctx: &str,
) -> Result<(), String> {
    let mut fast = FastSim::new(t.clone());
    let outs = bat.eval_batch(batch);
    if outs.len() != batch.len() {
        return Err(format!("{ctx}: lane count {} != {}", outs.len(), batch.len()));
    }
    for (l, ((out, run), cfg)) in outs.iter().zip(batch).enumerate() {
        let want = fast.simulate(cfg);
        if *out != want {
            return Err(format!(
                "{ctx} lane {l}: batched {out:?} != fast {want:?} at cfg {cfg:?}"
            ));
        }
        if run.total_ops != t.total_ops() as u64 {
            return Err(format!("{ctx} lane {l}: total_ops {run:?}"));
        }
    }
    Ok(())
}

#[test]
fn batched_lanes_agree_on_every_suite_design() {
    // One BatchedSim per design, reused across the lane grid — ragged
    // re-sizing of the SoA scratch between batches is part of the test.
    for name in suite_with_specials() {
        let t = trace_of(name);
        let mut bat = BatchedSim::new(t.clone());
        let ub = t.upper_bounds();
        let mut rng = Rng::new(0xBA7C ^ name.len() as u64);
        for &k in &[1usize, 3, 8] {
            let batch = random_lane_batch(&mut rng, &ub, k);
            assert_lanes_match_fast(&t, &mut bat, &batch, &format!("{name} K={k}"))
                .unwrap_or_else(|e| panic!("{e}"));
        }
    }
}

#[test]
fn property_batched_lane_grid_on_random_designs() {
    // The full K grid (incl. 64), ragged final batches and duplicate
    // configurations, on random layered designs.
    prop::check(
        "batched == fast per lane across the lane grid",
        prop::iters(20),
        |rng| {
            let design = random_layered_design(rng);
            let t = Arc::new(collect_trace(&design, &[]).map_err(|e| e.to_string())?);
            let mut bat = BatchedSim::new(t.clone());
            let ub = t.upper_bounds();
            let k = *rng.choose(&LANE_GRID);
            assert_lanes_match_fast(
                &t,
                &mut bat,
                &random_lane_batch(rng, &ub, k),
                &format!("K={k}"),
            )?;
            // A ragged follow-up batch (K not from the grid) reuses the
            // same simulator's scratch at a different width.
            let ragged = 1 + rng.index(5);
            assert_lanes_match_fast(
                &t,
                &mut bat,
                &random_lane_batch(rng, &ub, ragged),
                &format!("ragged K={ragged}"),
            )
        },
    );
}

#[test]
fn batched_lanes_split_deadlock_boundaries() {
    // One batch holding lanes on both sides of the feasibility threshold
    // (x = n − 1): per-lane verdicts must split exactly, with fast's
    // blocked sets on the deadlocked lanes.
    let d = deadlock_boundary_design();
    for n in [5i64, 16] {
        let t = Arc::new(collect_trace(&d, &[n]).unwrap());
        let thresh = (n - 1) as u32;
        let batch: Vec<Box<[u32]>> = (thresh.saturating_sub(2)..=thresh + 2)
            .flat_map(|dx| [2u32, 3].map(|dy| vec![dx.max(1), dy].into_boxed_slice()))
            .collect();
        let mut bat = BatchedSim::new(t.clone());
        assert_lanes_match_fast(&t, &mut bat, &batch, &format!("boundary n={n}"))
            .unwrap_or_else(|e| panic!("{e}"));
        // Sanity: the batch genuinely mixes verdicts.
        let outs = bat.eval_batch(&batch);
        assert!(outs.iter().any(|(o, _)| o.is_deadlock()), "n={n}");
        assert!(outs.iter().any(|(o, _)| !o.is_deadlock()), "n={n}");
    }
}

#[test]
fn batched_lanes_cover_srl_bram_flips() {
    // The SRL↔BRAM read-latency flip on the wide channel is a per-lane
    // edge weight: lanes on both sides of the threshold share one walk.
    let d = pair_burst_design(32);
    let t = Arc::new(collect_trace(&d, &[]).unwrap());
    let batch: Vec<Box<[u32]>> = (0..24u32)
        .map(|i| {
            let c_depth = if i % 2 == 0 { 2 } else { 3 + (i % 3) };
            vec![8u32, c_depth, 8].into_boxed_slice()
        })
        .collect();
    let mut bat = BatchedSim::new(t.clone());
    assert_lanes_match_fast(&t, &mut bat, &batch, "srl-bram")
        .unwrap_or_else(|e| panic!("{e}"));
}

#[test]
fn property_random_workload_banks_agree_batched() {
    // Bank-level lane batching: `ScenarioSim::eval_batch` over the
    // batched backend vs per-config fast-bank evaluation on random
    // multi-scenario workloads, early exit on and off.
    prop::check(
        "batched bank lanes == fast bank per config",
        prop::iters(15),
        |rng| {
            let w = random_workload(rng);
            let mut bat_bank =
                ScenarioSim::with_backend(&w, SimOptions::default(), BackendKind::Batched);
            let mut fast_bank = ScenarioSim::new(&w);
            let ub = w.upper_bounds();
            let k = *rng.choose(&LANE_GRID[..3]);
            let batch = random_lane_batch(rng, &ub, k);
            for early in [false, true] {
                let lanes = bat_bank.eval_batch(&batch, early);
                for (l, (le, cfg)) in lanes.iter().zip(&batch).enumerate() {
                    let want = fast_bank.simulate(cfg).latency();
                    prop_check(
                        le.latency == want,
                        format!("early={early} lane {l}: {:?} != {want:?} at {cfg:?}", le.latency),
                    )?;
                    prop_check(
                        le.gap == fast_bank.last_gap(),
                        format!("early={early} lane {l}: gap diverged at {cfg:?}"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

type HistoryRecord = Vec<(Box<[u32]>, Option<u64>, u32)>;
type FrontRecord = Vec<(Option<u64>, u32, Box<[u32]>)>;

fn history_of(ev: &Evaluator) -> HistoryRecord {
    ev.history
        .iter()
        .map(|p| (p.depths.clone(), p.latency, p.bram))
        .collect()
}

fn front_of(ev: &Evaluator) -> FrontRecord {
    ev.pareto()
        .iter()
        .map(|p| (p.latency, p.bram, p.depths.clone()))
        .collect()
}

#[test]
fn engine_identity_for_all_optimizers_under_compiled_on_a_workload() {
    // fig2's 3-scenario workload is deadlock-heavy, so the oracle, the
    // clamp and the early-exit path all engage *on top of* the compiled
    // backend — and every optimizer (greedy's stats path included) must
    // still produce the exact fast-backend history and front, serial and
    // --jobs 4.
    let w = Arc::new(bench_suite::build_workload("fig2").unwrap());
    let space = Space::from_workload(&w);
    for name in opt::OPTIMIZER_NAMES {
        for jobs in [1usize, 4] {
            let run = |kind: BackendKind| {
                let mut ev = Evaluator::for_workload_with_sim(w.clone(), jobs, kind);
                let mut o = opt::by_name(name, 42).unwrap();
                drive(&mut *o, &mut ev, &space, 90);
                let s = ev.stats();
                assert_eq!(
                    s.cache_hits + s.oracle_hits + s.sims,
                    s.proposals,
                    "{name} jobs={jobs} {:?}: accounting invariant broken",
                    kind
                );
                (history_of(&ev), front_of(&ev), s.sims)
            };
            let (fh, ff, fsims) = run(BackendKind::Fast);
            let (ch, cf, csims) = run(BackendKind::Compiled);
            assert_eq!(fh, ch, "{name} jobs={jobs}: history diverged");
            assert_eq!(ff, cf, "{name} jobs={jobs}: Pareto front diverged");
            assert_eq!(fsims, csims, "{name} jobs={jobs}: sim counts diverged");
        }
    }
}

#[test]
fn engine_identity_for_all_optimizers_under_batched_on_a_workload() {
    // The lane-batched backend replaces sticky pool dispatch with lane
    // packing, so serial and --jobs 4 share a code path — but both must
    // still reproduce the fast backend's exact history, front and sim
    // count for every optimizer, with the pruning layers on and off
    // (early exit changes which lanes ride later walks, never results).
    let w = Arc::new(bench_suite::build_workload("fig2").unwrap());
    let space = Space::from_workload(&w);
    for name in opt::OPTIMIZER_NAMES {
        for jobs in [1usize, 4] {
            for prune in [true, false] {
                let run = |kind: BackendKind| {
                    let mut ev = Evaluator::for_workload_with_sim(w.clone(), jobs, kind);
                    ev.set_prune(prune);
                    let mut o = opt::by_name(name, 42).unwrap();
                    drive(&mut *o, &mut ev, &space, 90);
                    let s = ev.stats();
                    assert_eq!(
                        s.cache_hits + s.oracle_hits + s.sims,
                        s.proposals,
                        "{name} jobs={jobs} prune={prune} {kind:?}: accounting invariant broken"
                    );
                    if kind == BackendKind::Batched {
                        assert!(
                            s.lanes_packed >= s.batch_walks,
                            "{name} jobs={jobs} prune={prune}: lane telemetry inconsistent"
                        );
                    }
                    (history_of(&ev), front_of(&ev), s.sims)
                };
                let (fh, ff, fsims) = run(BackendKind::Fast);
                let (bh, bf, bsims) = run(BackendKind::Batched);
                assert_eq!(fh, bh, "{name} jobs={jobs} prune={prune}: history diverged");
                assert_eq!(ff, bf, "{name} jobs={jobs} prune={prune}: front diverged");
                assert_eq!(
                    fsims, bsims,
                    "{name} jobs={jobs} prune={prune}: sim counts diverged"
                );
            }
        }
    }
}

#[test]
fn engine_bounds_counters_are_jobs_and_backend_invariant() {
    // The analytic-bounds telemetry is part of the deterministic
    // contract: a run's floor-hit and cap-tightening counts must not
    // depend on the worker count or the simulation backend, because the
    // short-circuit fires per proposal, before any dispatch decision.
    let w = Arc::new(bench_suite::build_workload("fig2").unwrap());
    let space = Space::from_workload(&w);
    for name in ["greedy", "grouped_sa"] {
        let mut seen: Vec<(u64, u64)> = Vec::new();
        for kind in [BackendKind::Fast, BackendKind::Compiled, BackendKind::Batched] {
            for jobs in [1usize, 4] {
                let mut ev = Evaluator::for_workload_with_sim(w.clone(), jobs, kind);
                // A sub-floor probe (fig2's Baseline-Min sits below the
                // x floor of n − 1) so at least one hit is guaranteed.
                ev.eval(&w.baseline_min());
                let mut o = opt::by_name(name, 42).unwrap();
                drive(&mut *o, &mut ev, &space, 60);
                let s = ev.stats();
                seen.push((s.bounds_floor_hits, s.cap_tightenings));
            }
        }
        assert!(seen[0].0 >= 1, "{name}: the sub-floor probe must hit the floor");
        for v in &seen[1..] {
            assert_eq!(
                &seen[0], v,
                "{name}: bounds counters vary across jobs/backends"
            );
        }
    }
}

#[test]
fn engine_identity_for_all_optimizers_under_compiled_single_trace() {
    // Static single-trace engine (gesummv): every optimizer, serial, with
    // the clamp region reachable through the padded proposals some
    // optimizers generate.
    let t = trace_of("gesummv");
    let space = Space::from_trace(&t);
    for name in opt::OPTIMIZER_NAMES {
        let run = |kind: BackendKind| {
            let w = Arc::new(fifoadvisor::trace::workload::Workload::single(t.clone()));
            let mut ev = Evaluator::for_workload_with_sim(w, 1, kind);
            let mut o = opt::by_name(name, 7).unwrap();
            drive(&mut *o, &mut ev, &space, 100);
            (history_of(&ev), front_of(&ev))
        };
        let fast = run(BackendKind::Fast);
        assert_eq!(
            fast,
            run(BackendKind::Compiled),
            "{name}: single-trace history/front diverged (compiled)"
        );
        assert_eq!(
            fast,
            run(BackendKind::Batched),
            "{name}: single-trace history/front diverged (batched)"
        );
    }
}
