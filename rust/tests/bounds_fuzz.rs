//! Differential fuzzing of the analytic depth bounds (`opt::bounds`):
//! randomized walks over the suite designs, the shared fixture designs
//! and random layered DAGs / workloads asserting that
//!
//! - **floors are sound** — no feasible configuration exists below a
//!   derived deadlock floor, for *any* sibling depths (checked against
//!   `FastSim`/`ScenarioSim`, and against the golden reference on the
//!   deadlock-boundary fixture where the floor is exactly the paper's
//!   `n − 1` threshold),
//! - **tightened caps preserve outcomes** — a configuration clamped
//!   through a [`Canonicalizer`] built on the analytic caps is
//!   outcome-identical to its raw counterpart (full `SimOutcome`
//!   equality plus per-scenario latencies on workloads), including on
//!   the wide-channel fixtures where depth changes flip SRL↔BRAM read
//!   latency classes, and
//! - **the engine's floor short-circuit is invisible** — an
//!   [`EvalEngine`] with bounds on agrees with a plain scenario bank on
//!   every probe, below-floor probes included.
//!
//! Cases run under `util::prop::check`, so a failure reports its seed
//! (and the CI fuzz job cranks counts via `FIFOADVISOR_FUZZ_ITERS` and
//! uploads failing seeds through `FIFOADVISOR_FUZZ_ARTIFACT_DIR`).

use fifoadvisor::bench_suite;
use fifoadvisor::dse::EvalEngine;
use fifoadvisor::opt::bounds::DepthBounds;
use fifoadvisor::opt::dominance::Canonicalizer;
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::golden::simulate_golden;
use fifoadvisor::sim::{ScenarioSim, SimOptions};
use fifoadvisor::trace::{collect_trace, Trace};
use fifoadvisor::util::prop::{
    check, deadlock_boundary_design, iters, pair_burst_design, random_depths,
    random_layered_design, random_workload, suite_with_specials,
};
use fifoadvisor::util::Rng;
use std::sync::Arc;

fn trace_of(name: &str) -> Arc<Trace> {
    let bd = bench_suite::build(name);
    Arc::new(collect_trace(&bd.design, &bd.args).unwrap())
}

fn widths_of(t: &Trace) -> Vec<u32> {
    t.channels.iter().map(|c| c.width_bits).collect()
}

/// Clamp-differential on one trace: random over-cap configurations must
/// be outcome-identical (latency AND blocked sets) to their canonical
/// forms under a canonicalizer built on the *analytic* caps.
fn assert_caps_preserve_outcomes(name: &str, t: &Arc<Trace>, rng: &mut Rng, steps: u64) {
    let b = DepthBounds::for_trace(t);
    let widths = widths_of(t);
    let canon = Canonicalizer::new(b.caps.clone(), &widths);
    let mut raw_sim = FastSim::new(t.clone());
    let mut canon_sim = FastSim::new(t.clone());
    let ub = t.upper_bounds();
    for step in 0..steps {
        let cfg = random_depths(rng, &ub, 17);
        if let Some(ccfg) = canon.canonical(&cfg) {
            let raw_out = raw_sim.simulate(&cfg);
            let canon_out = canon_sim.simulate(&ccfg);
            assert_eq!(
                raw_out, canon_out,
                "{name} step {step}: tightened-cap clamp changed the outcome, \
                 raw {cfg:?} vs canon {ccfg:?} (caps {:?})",
                b.caps
            );
            assert!(canon.canonical(&ccfg).is_none(), "{name}: not idempotent");
        }
    }
}

#[test]
fn no_feasible_config_below_the_floor_on_any_suite_design() {
    for name in suite_with_specials() {
        let t = trace_of(name);
        let b = DepthBounds::for_trace(&t);
        let mut sim = FastSim::new(t.clone());
        let ub = t.upper_bounds();
        let mut rng = Rng::new(0xF100 ^ name.len() as u64);
        for (ch, &f) in b.floors.iter().enumerate() {
            if f < 2 {
                continue; // depth 0 is unrepresentable: nothing to prove
            }
            // The floor claims deadlock for ANY sibling depths — fuzz
            // them, padded past the caps.
            for _ in 0..3 {
                let mut cfg = random_depths(&mut rng, &ub, 6);
                cfg[ch] = rng.range_u32(1, f - 1);
                assert!(
                    sim.simulate(&cfg).is_deadlock(),
                    "{name} ch {ch}: {cfg:?} runs below the floor {f}"
                );
            }
        }
    }
}

#[test]
fn floors_are_sound_on_random_layered_designs() {
    check("bounds floor sound on layered DAGs", iters(12), |rng| {
        let d = random_layered_design(rng);
        let t = Arc::new(collect_trace(&d, &[]).map_err(|e| e.to_string())?);
        let b = DepthBounds::for_trace(&t);
        let mut sim = FastSim::new(t.clone());
        let ub = t.upper_bounds();
        for (ch, &f) in b.floors.iter().enumerate() {
            if f < 2 {
                continue;
            }
            let mut cfg = random_depths(rng, &ub, 3);
            cfg[ch] = rng.range_u32(1, f - 1);
            if !sim.simulate(&cfg).is_deadlock() {
                return Err(format!("ch {ch}: {cfg:?} runs below the floor {f}"));
            }
        }
        // Caps on the same design: clamp is outcome-invisible.
        assert_caps_preserve_outcomes("layered", &t, rng, 4);
        Ok(())
    });
}

#[test]
fn boundary_floor_is_exact_against_the_golden_simulator() {
    check("boundary floor exact vs golden", iters(8), |rng| {
        let n = 3 + rng.below(12) as i64;
        let d = deadlock_boundary_design();
        let t = Arc::new(collect_trace(&d, &[n]).map_err(|e| e.to_string())?);
        let b = DepthBounds::for_trace(&t);
        if b.floors[0] as i64 != n - 1 {
            return Err(format!(
                "n = {n}: x floor {} != the paper threshold {}",
                b.floors[0],
                n - 1
            ));
        }
        // One below the floor deadlocks in the golden reference even
        // with every sibling fully relaxed; at the floor the design
        // runs with the sibling at the Vitis minimum.
        let ub = t.upper_bounds();
        let mut below: Vec<u32> = ub.iter().map(|&u| u.max(2) + 2).collect();
        below[0] = rng.range_u32(1, b.floors[0] - 1);
        if !simulate_golden(&t, &below, SimOptions::default()).is_deadlock() {
            return Err(format!("golden ran {below:?} below the floor"));
        }
        let at = vec![b.floors[0], 2];
        if simulate_golden(&t, &at, SimOptions::default()).is_deadlock() {
            return Err(format!("golden deadlocked {at:?} at the floor — floor too high"));
        }
        Ok(())
    });
}

#[test]
fn tightened_caps_preserve_outcomes_on_every_design() {
    for name in suite_with_specials() {
        let t = trace_of(name);
        let mut rng = Rng::new(0xCA95 ^ name.len() as u64);
        assert_caps_preserve_outcomes(name, &t, &mut rng, iters(10));
    }
}

#[test]
fn tightened_caps_hold_across_srl_bram_flips() {
    // The pair-burst fixture's 512-bit channel crosses the SRL↔BRAM
    // read-latency class inside the fuzzed depth range — the case the
    // cap's +1 safety margin exists for.
    check("caps sound across SRL/BRAM flips", iters(10), |rng| {
        let n = 2 + rng.below(12);
        let d = pair_burst_design(n);
        let t = Arc::new(collect_trace(&d, &[]).map_err(|e| e.to_string())?);
        assert_caps_preserve_outcomes("pairburst", &t, rng, 8);
        Ok(())
    });
}

#[test]
fn workload_bounds_agree_with_the_scenario_bank() {
    check("workload floors and caps sound", iters(8), |rng| {
        let w = Arc::new(random_workload(rng));
        let b = DepthBounds::for_workload(&w);
        let ub = w.upper_bounds();
        // Floors merge to the worst scenario: below one, some scenario
        // deadlocks, which makes the whole workload infeasible.
        let mut bank = ScenarioSim::new(&w);
        for (ch, &f) in b.floors.iter().enumerate() {
            if f < 2 {
                continue;
            }
            let mut cfg = random_depths(rng, &ub, 3);
            cfg[ch] = rng.range_u32(1, f - 1);
            if bank.simulate(&cfg).latency().is_some() {
                return Err(format!("ch {ch}: workload ran {cfg:?} below the floor {f}"));
            }
        }
        // Caps preserve per-scenario latencies, not just the aggregate.
        let widths = widths_of(w.primary());
        let canon = Canonicalizer::new(b.caps.clone(), &widths);
        let mut raw_bank = ScenarioSim::new(&w);
        let mut canon_bank = ScenarioSim::new(&w);
        for _ in 0..4 {
            let cfg = random_depths(rng, &ub, 9);
            if let Some(ccfg) = canon.canonical(&cfg) {
                let raw = raw_bank.simulate(&cfg).latency();
                let can = canon_bank.simulate(&ccfg).latency();
                if raw != can {
                    return Err(format!("clamp diverged: {raw:?} vs {can:?} on {cfg:?}"));
                }
                if raw_bank.scenario_latencies() != canon_bank.scenario_latencies() {
                    return Err(format!("per-scenario latencies diverged on {cfg:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn engine_floor_short_circuit_matches_real_simulation() {
    check("engine floor short-circuit invisible", iters(8), |rng| {
        let w = Arc::new(random_workload(rng));
        let b = DepthBounds::for_workload(&w);
        let mut ev = EvalEngine::for_workload(w.clone(), 1);
        let mut bank = ScenarioSim::new(&w);
        let ub = w.upper_bounds();
        for _ in 0..6 {
            let mut cfg = random_depths(rng, &ub, 2);
            // Bias half the probes below a non-trivial floor so the
            // short-circuit path actually fires.
            if rng.chance(0.5) {
                let floored: Vec<(usize, u32)> = b
                    .floors
                    .iter()
                    .enumerate()
                    .filter(|(_, &f)| f >= 2)
                    .map(|(ch, &f)| (ch, f))
                    .collect();
                if !floored.is_empty() {
                    let (ch, f) = floored[rng.index(floored.len())];
                    cfg[ch] = rng.range_u32(1, f - 1);
                }
            }
            let (lat, _) = ev.eval(&cfg);
            let real = bank.simulate(&cfg).latency();
            if lat != real {
                return Err(format!(
                    "engine answered {lat:?} but the bank says {real:?} on {cfg:?}"
                ));
            }
        }
        Ok(())
    });
}
