//! Acceptance tests for the ask/tell engine refactor: every optimizer,
//! driven through the ask/tell protocol, produces **identical** results
//! (per-proposal latency and BRAM, and the extracted Pareto front) on a
//! serial engine and on a `--jobs 4` engine — worker scheduling must
//! never leak into the search. (The batched-throughput check lives in
//! `engine_throughput.rs` so it gets the machine to itself.)

use fifoadvisor::bench_suite;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::{self, Space};
use fifoadvisor::trace::{collect_trace, Trace};
use std::sync::Arc;

fn trace_of(name: &str) -> Arc<Trace> {
    let bd = bench_suite::build(name);
    Arc::new(collect_trace(&bd.design, &bd.args).unwrap())
}

/// (depths, latency, bram) per history entry + the Pareto front.
type RunRecord = (Vec<(Box<[u32]>, Option<u64>, u32)>, Vec<(u64, u32)>);

fn run_with_jobs(trace: &Arc<Trace>, space: &Space, opt_name: &str, jobs: usize) -> RunRecord {
    let mut ev = Evaluator::parallel(trace.clone(), jobs);
    let mut o = opt::by_name(opt_name, 42).unwrap();
    drive(&mut *o, &mut ev, space, 150);
    let history = ev
        .history
        .iter()
        .map(|p| (p.depths.clone(), p.latency, p.bram))
        .collect();
    let front = ev
        .pareto()
        .iter()
        .map(|p| (p.latency.unwrap(), p.bram))
        .collect();
    (history, front)
}

#[test]
fn every_optimizer_is_identical_serial_vs_jobs_4() {
    let trace = trace_of("gesummv");
    let space = Space::from_trace(&trace);
    for name in opt::OPTIMIZER_NAMES {
        let serial = run_with_jobs(&trace, &space, name, 1);
        let parallel = run_with_jobs(&trace, &space, name, 4);
        assert!(
            !serial.0.is_empty(),
            "{name}: optimizer proposed nothing through ask/tell"
        );
        assert_eq!(
            serial.0, parallel.0,
            "{name}: history diverged between serial and --jobs 4"
        );
        assert_eq!(
            serial.1, parallel.1,
            "{name}: Pareto front diverged between serial and --jobs 4"
        );
    }
}

#[test]
fn deadlock_heavy_design_is_identical_too() {
    // fig2's tiny pruned space exercises dedup + deadlock caching.
    let trace = trace_of("fig2");
    let space = Space::from_trace(&trace);
    for name in ["exhaustive", "grouped_sa", "nsga2", "vitis_hunter"] {
        let serial = run_with_jobs(&trace, &space, name, 1);
        let parallel = run_with_jobs(&trace, &space, name, 4);
        assert_eq!(serial.0, parallel.0, "{name} diverged on fig2");
    }
}

#[test]
fn engine_stats_track_cache_and_throughput() {
    let trace = trace_of("gesummv");
    let space = Space::from_trace(&trace);
    let mut ev = Evaluator::parallel(trace.clone(), 4);
    drive(&mut *opt::by_name("grouped_sa", 3).unwrap(), &mut ev, &space, 120);
    let s = ev.stats();
    assert_eq!(s.proposals as usize, ev.n_evals());
    assert_eq!(s.sims, ev.n_sim, "fresh engine: run sims == lifetime sims");
    assert_eq!(
        s.cache_hits + s.oracle_hits + s.sims,
        s.proposals,
        "every proposal is a memo hit, an oracle answer, or a simulation"
    );
    assert!(ev.sims_per_sec() > 0.0);
    assert!(ev.proposals_per_sec() >= ev.sims_per_sec());
    assert!(ev.worker_utilization() >= 0.0 && ev.worker_utilization() <= 1.0);
    assert!(ev.cache_shards().is_power_of_two());
}
