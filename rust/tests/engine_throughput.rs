//! Throughput acceptance check for the persistent worker pool: batched
//! evaluation of a 256-configuration batch must be at least 2× faster
//! than serial evaluation when 4 cores are available. Kept in its own
//! test binary so no sibling test competes for CPU during measurement
//! (cargo runs test binaries one at a time).

use fifoadvisor::bench_suite;
use fifoadvisor::dse::pool::parallel_latencies;
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::Rng;
use std::sync::Arc;
use std::time::Instant;

#[test]
fn batched_evaluation_beats_serial_by_2x_on_4_cores() {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores < 4 {
        eprintln!("skipping throughput check: only {cores} cores available");
        return;
    }
    let bd = bench_suite::build("gemm");
    let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
    let proto = FastSim::new(trace.clone());
    let ub = trace.upper_bounds();
    let mut rng = Rng::new(7);
    // Feasible-leaning configurations so every simulation does real work.
    let configs: Vec<Box<[u32]>> = (0..256)
        .map(|_| {
            ub.iter()
                .map(|&u| rng.range_u32((u / 2).max(2), u.max(2)))
                .collect::<Box<[u32]>>()
        })
        .collect();
    // Warm up (first touch pays allocation + page faults) and pin the
    // expected results.
    let expected = parallel_latencies(&proto, &configs, 1);

    let best_of = |threads: usize| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            let out = parallel_latencies(&proto, &configs, threads);
            best = best.min(t0.elapsed().as_secs_f64());
            assert_eq!(out, expected, "parallel run changed results");
        }
        best
    };
    let t_serial = best_of(1);
    let t_parallel = best_of(4);
    let speedup = t_serial / t_parallel.max(1e-9);
    eprintln!(
        "batch of {} configs: serial {t_serial:.4}s, 4 workers {t_parallel:.4}s -> {speedup:.2}x",
        configs.len()
    );
    assert!(
        speedup >= 2.0,
        "persistent pool speedup {speedup:.2}x < 2x on {cores} cores"
    );
}
