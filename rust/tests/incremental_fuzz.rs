//! Differential fuzzing of delta-incremental re-simulation.
//!
//! Three simulators must agree *exactly* on every configuration of every
//! design:
//!
//! - a **warm** [`FastSim`] fed a whole mutation chain (so nearly every
//!   call is a delta replay against its retained schedule),
//! - a **cold** `FastSim` with incremental mode disabled (full replay
//!   every call — the old behaviour), and
//! - periodically, the structurally independent **golden** cycle-stepped
//!   simulator.
//!
//! Full [`SimOutcome`]s are compared — latency *and* the deadlock blocked
//! sets — so a delta replay that reaches the wrong fixpoint cannot hide.
//! The mutation chains are DSE-shaped (1–2 channel deltas with occasional
//! full re-randomization; the shared `util::prop` generator set), and the
//! design families deliberately cover the simulator's fast paths:
//! homogeneous write/read bursts, alternating pair-read bursts (the
//! matmul PE pattern), SRL↔BRAM read-latency flips on wide channels, and
//! deadlock↔feasible boundaries. Compiled-vs-fast conformance for the
//! same corpus lives in `tests/backend_conformance.rs`.

use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::golden::simulate_golden;
use fifoadvisor::sim::SimOptions;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::prop::{
    deadlock_boundary_design, mutate_depths, pair_burst_design, random_layered_design,
};
use fifoadvisor::util::{prop, Rng};
use std::sync::Arc;

/// Drive `steps` mutation steps, asserting warm == cold (full outcome)
/// and, every few steps, fast == golden (latency/deadlock verdict).
fn fuzz_design(design: &fifoadvisor::ir::Design, args: &[i64], rng: &mut Rng, steps: usize) {
    let t = Arc::new(collect_trace(design, args).expect("trace collection"));
    let mut warm = FastSim::new(t.clone());
    let mut cold = FastSim::new(t.clone());
    cold.set_incremental(false);
    let ub = t.upper_bounds();
    let mut cfg: Vec<u32> = ub.iter().map(|&u| rng.range_u32(1, u.max(2))).collect();
    for step in 0..steps {
        let w = warm.simulate(&cfg);
        let c = cold.simulate(&cfg);
        assert_eq!(
            w, c,
            "step {step}: warm (incremental) != cold full replay, cfg {cfg:?}"
        );
        if step % 5 == 0 {
            let g = simulate_golden(&t, &cfg, SimOptions::default());
            assert_eq!(
                w.latency(),
                g.latency(),
                "step {step}: fast != golden, cfg {cfg:?}"
            );
        }
        mutate_depths(rng, &mut cfg, &ub);
    }
    // Retention sanity: an identical-configuration re-run is always an
    // incremental (zero-replay) hit after any history.
    let w1 = warm.simulate(&cfg);
    let w2 = warm.simulate(&cfg);
    assert_eq!(w1, w2);
    assert!(warm.last_run().incremental);
    assert_eq!(warm.last_run().replayed_ops, 0);
}

#[test]
fn fuzz_pair_burst_design() {
    let mut rng = Rng::new(0x14C0);
    let d = pair_burst_design(48);
    fuzz_design(&d, &[], &mut rng, prop::iters(120) as usize);
}

#[test]
fn fuzz_deadlock_boundary() {
    let mut rng = Rng::new(0xB0DA);
    let d = deadlock_boundary_design();
    for n in [4i64, 16, 33] {
        fuzz_design(&d, &[n], &mut rng, prop::iters(80) as usize);
    }
}

#[test]
fn fuzz_srl_bram_toggle_chain() {
    // Deterministic worst case for latency invalidation: toggle a wide
    // channel back and forth across the SRL threshold (512-bit channel:
    // depth 2 → SRL rl=1, depth 3 → BRAM rl=2).
    let d = pair_burst_design(32);
    let t = Arc::new(collect_trace(&d, &[]).unwrap());
    let mut warm = FastSim::new(t.clone());
    let mut cold = FastSim::new(t.clone());
    cold.set_incremental(false);
    for i in 0..24u32 {
        let c_depth = if i % 2 == 0 { 2 } else { 3 + (i % 3) };
        let cfg = [8u32, c_depth, 8];
        let w = warm.simulate(&cfg);
        let c = cold.simulate(&cfg);
        assert_eq!(w, c, "toggle {i}, cfg {cfg:?}");
        let g = simulate_golden(&t, &cfg, SimOptions::default());
        assert_eq!(w.latency(), g.latency(), "toggle {i} vs golden");
    }
}

#[test]
fn property_random_designs_incremental_equals_cold_full() {
    prop::check(
        "incremental == cold == golden on random designs",
        prop::iters(40),
        |rng| {
            let design = random_layered_design(rng);
            let t = Arc::new(collect_trace(&design, &[]).map_err(|e| e.to_string())?);
            let mut warm = FastSim::new(t.clone());
            let mut cold = FastSim::new(t.clone());
            cold.set_incremental(false);
            let ub = t.upper_bounds();
            let mut cfg: Vec<u32> = ub.iter().map(|&u| rng.range_u32(1, u.max(2))).collect();
            for step in 0..30 {
                let w = warm.simulate(&cfg);
                let c = cold.simulate(&cfg);
                if w != c {
                    return Err(format!(
                        "step {step}: warm {w:?} != cold {c:?} at cfg {cfg:?}"
                    ));
                }
                if step % 6 == 0 {
                    let g = simulate_golden(&t, &cfg, SimOptions::default());
                    if w.latency() != g.latency() {
                        return Err(format!(
                            "step {step}: fast {:?} != golden {:?} at cfg {cfg:?}",
                            w.latency(),
                            g.latency()
                        ));
                    }
                }
                mutate_depths(rng, &mut cfg, &ub);
            }
            Ok(())
        },
    );
}

#[test]
fn warm_simulator_matches_freshly_built_one() {
    // Retention must never leak across configurations: after an arbitrary
    // history, a warm simulator equals a brand-new one on the same config.
    let d = pair_burst_design(40);
    let t = Arc::new(collect_trace(&d, &[]).unwrap());
    let mut warm = FastSim::new(t.clone());
    let mut rng = Rng::new(7);
    let ub = t.upper_bounds();
    let mut cfg: Vec<u32> = ub.iter().map(|&u| u.max(2)).collect();
    for _ in 0..40 {
        mutate_depths(&mut rng, &mut cfg, &ub);
        let w = warm.simulate(&cfg);
        let f = FastSim::new(t.clone()).simulate(&cfg);
        assert_eq!(w, f, "cfg {cfg:?}");
    }
}
