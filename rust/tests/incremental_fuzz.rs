//! Differential fuzzing of delta-incremental re-simulation.
//!
//! Three simulators must agree *exactly* on every configuration of every
//! design:
//!
//! - a **warm** [`FastSim`] fed a whole mutation chain (so nearly every
//!   call is a delta replay against its retained schedule),
//! - a **cold** `FastSim` with incremental mode disabled (full replay
//!   every call — the old behaviour), and
//! - periodically, the structurally independent **golden** cycle-stepped
//!   simulator.
//!
//! Full [`SimOutcome`]s are compared — latency *and* the deadlock blocked
//! sets — so a delta replay that reaches the wrong fixpoint cannot hide.
//! The mutation chains are DSE-shaped (1–2 channel deltas with occasional
//! full re-randomization), and the design families deliberately cover the
//! simulator's fast paths: homogeneous write/read bursts, alternating
//! pair-read bursts (the matmul PE pattern), SRL↔BRAM read-latency flips
//! on wide channels, and deadlock↔feasible boundaries.

use fifoadvisor::ir::{DesignBuilder, Expr};
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::golden::simulate_golden;
use fifoadvisor::sim::SimOptions;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::{prop, Rng};
use std::sync::Arc;

/// One fuzz step: mutate 1–2 channels (occasionally re-randomize all).
fn mutate(rng: &mut Rng, cfg: &mut [u32], ub: &[u32]) {
    let full = rng.chance(0.05);
    if full {
        for (d, &u) in cfg.iter_mut().zip(ub) {
            *d = rng.range_u32(1, u.max(2) + 2);
        }
        return;
    }
    let n_mut = if rng.chance(0.7) { 1 } else { 2 };
    for _ in 0..n_mut {
        let i = rng.index(cfg.len());
        let u = ub[i].max(2);
        cfg[i] = match rng.below(5) {
            // Corners and near-boundary values: SRL thresholds, the Vitis
            // minimum, ±1 steps (the SA move shape), and uniform.
            0 => 1,
            1 => 2,
            2 => u,
            3 => {
                if rng.chance(0.5) {
                    (cfg[i] + 1).min(u + 2)
                } else {
                    cfg[i].saturating_sub(1).max(1)
                }
            }
            _ => rng.range_u32(1, u + 2),
        };
    }
}

/// Drive `steps` mutation steps, asserting warm == cold (full outcome)
/// and, every few steps, fast == golden (latency/deadlock verdict).
fn fuzz_design(design: &fifoadvisor::ir::Design, args: &[i64], rng: &mut Rng, steps: usize) {
    let t = Arc::new(collect_trace(design, args).expect("trace collection"));
    let mut warm = FastSim::new(t.clone());
    let mut cold = FastSim::new(t.clone());
    cold.set_incremental(false);
    let ub = t.upper_bounds();
    let mut cfg: Vec<u32> = ub.iter().map(|&u| rng.range_u32(1, u.max(2))).collect();
    for step in 0..steps {
        let w = warm.simulate(&cfg);
        let c = cold.simulate(&cfg);
        assert_eq!(
            w, c,
            "step {step}: warm (incremental) != cold full replay, cfg {cfg:?}"
        );
        if step % 5 == 0 {
            let g = simulate_golden(&t, &cfg, SimOptions::default());
            assert_eq!(
                w.latency(),
                g.latency(),
                "step {step}: fast != golden, cfg {cfg:?}"
            );
        }
        mutate(rng, &mut cfg, &ub);
    }
    // Retention sanity: an identical-configuration re-run is always an
    // incremental (zero-replay) hit after any history.
    let w1 = warm.simulate(&cfg);
    let w2 = warm.simulate(&cfg);
    assert_eq!(w1, w2);
    assert!(warm.last_run().incremental);
    assert_eq!(warm.last_run().replayed_ops, 0);
}

/// Bursty producers + an alternating pair-read consumer (the matmul PE
/// access pattern): exercises the homogeneous-run and pair-burst fast
/// paths. Channel `c` is wide, so small depth changes flip SRL↔BRAM.
fn pair_burst_design(n: u64) -> fifoadvisor::ir::Design {
    let mut b = DesignBuilder::new("pairburst", 0);
    let a = b.channel("a", 32);
    let c = b.channel("c", 512);
    let s = b.channel("s", 32);
    b.process("pa", move |p| {
        p.for_n(n, |p, _| p.write(a, Expr::c(0)));
    });
    b.process("pc", move |p| {
        p.for_n(n, |p, _| p.write(c, Expr::c(0)));
    });
    b.process("pe", move |p| {
        p.for_n(n, |p, _| {
            let _ = p.read(a);
            let _ = p.read(c);
        });
        p.for_n(n, |p, _| p.write(s, Expr::c(0)));
    });
    b.process("sink", move |p| {
        p.for_n(n, |p, _| {
            let _ = p.read(s);
        });
    });
    b.build()
}

/// Fig. 2-shaped design: feasibility flips as depth(x) crosses n-1, so
/// mutation chains repeatedly cross the deadlock boundary.
fn deadlock_boundary_design() -> fifoadvisor::ir::Design {
    let mut b = DesignBuilder::new("boundary", 1);
    let x = b.channel("x", 32);
    let y = b.channel("y", 256);
    b.process("producer", |p| {
        p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
        p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
    });
    b.process("consumer", |p| {
        p.for_expr(Expr::arg(0), |p, _| {
            let _ = p.read(x);
            let _ = p.read(y);
        });
    });
    b.build()
}

/// Random layered DAG (same family as `sim_equivalence.rs`, plus wide
/// channels for SRL↔BRAM flips and zero-delay bursts).
fn random_layered_design(rng: &mut Rng) -> fifoadvisor::ir::Design {
    let n_stages = 2 + rng.index(3);
    let mut b = DesignBuilder::new("rand", 0);
    let mut prev: Option<(Vec<usize>, u64)> = None;
    for s in 0..n_stages {
        let width = *rng.choose(&[8u32, 32, 64, 512]);
        let fanout = 1 + rng.index(3);
        let tokens = 1 + rng.below(20);
        let chans: Vec<usize> = (0..fanout)
            .map(|i| b.channel(&format!("c{s}_{i}"), width))
            .collect();
        // Bias toward zero delays so homogeneous bursts form.
        let delay_in = if rng.chance(0.6) { 0 } else { rng.below(3) as u32 };
        let delay_out = if rng.chance(0.6) { 0 } else { rng.below(3) as u32 };
        match prev.clone() {
            None => {
                let cc = chans.clone();
                b.process(&format!("src{s}"), move |p| {
                    p.for_n(tokens, |p, _| {
                        for &c in &cc {
                            p.delay(delay_out);
                            p.write(c, Expr::c(1));
                        }
                    });
                });
            }
            Some((inputs, in_tokens)) => {
                let cc = chans.clone();
                let ins = inputs.clone();
                b.process(&format!("stage{s}"), move |p| {
                    p.for_n(in_tokens, |p, _| {
                        for &c in &ins {
                            p.delay(delay_in);
                            let _ = p.read(c);
                        }
                    });
                    p.for_n(tokens, |p, _| {
                        for &c in &cc {
                            p.delay(delay_out);
                            p.write(c, Expr::c(1));
                        }
                    });
                });
            }
        }
        prev = Some((chans, tokens));
    }
    let (inputs, in_tokens) = prev.unwrap();
    b.process("sink", move |p| {
        p.for_n(in_tokens, |p, _| {
            for &c in &inputs {
                let _ = p.read(c);
            }
        });
    });
    b.build()
}

#[test]
fn fuzz_pair_burst_design() {
    let mut rng = Rng::new(0x14C0);
    let d = pair_burst_design(48);
    fuzz_design(&d, &[], &mut rng, 120);
}

#[test]
fn fuzz_deadlock_boundary() {
    let mut rng = Rng::new(0xB0DA);
    let d = deadlock_boundary_design();
    for n in [4i64, 16, 33] {
        fuzz_design(&d, &[n], &mut rng, 80);
    }
}

#[test]
fn fuzz_srl_bram_toggle_chain() {
    // Deterministic worst case for latency invalidation: toggle a wide
    // channel back and forth across the SRL threshold (512-bit channel:
    // depth 2 → SRL rl=1, depth 3 → BRAM rl=2).
    let d = pair_burst_design(32);
    let t = Arc::new(collect_trace(&d, &[]).unwrap());
    let mut warm = FastSim::new(t.clone());
    let mut cold = FastSim::new(t.clone());
    cold.set_incremental(false);
    for i in 0..24u32 {
        let c_depth = if i % 2 == 0 { 2 } else { 3 + (i % 3) };
        let cfg = [8u32, c_depth, 8];
        let w = warm.simulate(&cfg);
        let c = cold.simulate(&cfg);
        assert_eq!(w, c, "toggle {i}, cfg {cfg:?}");
        let g = simulate_golden(&t, &cfg, SimOptions::default());
        assert_eq!(w.latency(), g.latency(), "toggle {i} vs golden");
    }
}

#[test]
fn property_random_designs_incremental_equals_cold_full() {
    prop::check("incremental == cold == golden on random designs", 40, |rng| {
        let design = random_layered_design(rng);
        let t = Arc::new(collect_trace(&design, &[]).map_err(|e| e.to_string())?);
        let mut warm = FastSim::new(t.clone());
        let mut cold = FastSim::new(t.clone());
        cold.set_incremental(false);
        let ub = t.upper_bounds();
        let mut cfg: Vec<u32> = ub.iter().map(|&u| rng.range_u32(1, u.max(2))).collect();
        for step in 0..30 {
            let w = warm.simulate(&cfg);
            let c = cold.simulate(&cfg);
            if w != c {
                return Err(format!(
                    "step {step}: warm {w:?} != cold {c:?} at cfg {cfg:?}"
                ));
            }
            if step % 6 == 0 {
                let g = simulate_golden(&t, &cfg, SimOptions::default());
                if w.latency() != g.latency() {
                    return Err(format!(
                        "step {step}: fast {:?} != golden {:?} at cfg {cfg:?}",
                        w.latency(),
                        g.latency()
                    ));
                }
            }
            mutate(rng, &mut cfg, &ub);
        }
        Ok(())
    });
}

#[test]
fn warm_simulator_matches_freshly_built_one() {
    // Retention must never leak across configurations: after an arbitrary
    // history, a warm simulator equals a brand-new one on the same config.
    let d = pair_burst_design(40);
    let t = Arc::new(collect_trace(&d, &[]).unwrap());
    let mut warm = FastSim::new(t.clone());
    let mut rng = Rng::new(7);
    let ub = t.upper_bounds();
    let mut cfg: Vec<u32> = ub.iter().map(|&u| u.max(2)).collect();
    for _ in 0..40 {
        mutate(&mut rng, &mut cfg, &ub);
        let w = warm.simulate(&cfg);
        let f = FastSim::new(t.clone()).simulate(&cfg);
        assert_eq!(w, f, "cfg {cfg:?}");
    }
}
