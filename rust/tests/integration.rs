//! End-to-end integration: full DSE runs over suite designs, reproducing
//! the paper's qualitative claims at reduced budgets.

use fifoadvisor::bench_suite;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::objective::select_highlight;
use fifoadvisor::opt::{self, Optimizer, Space};
use fifoadvisor::trace::collect_trace;
use std::sync::Arc;

fn setup(name: &str, threads: usize) -> (Evaluator, Space) {
    let bd = bench_suite::build(name);
    let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
    let space = Space::from_trace(&t);
    (Evaluator::parallel(t, threads), space)
}

/// §IV-B headline: on a Stream-HLS design, the grouped optimizers find
/// configurations with large BRAM reductions at ~baseline latency.
/// (k15mmseq has the paper-typical knee: most of Baseline-Max's BRAM is
/// free to remove; gemm's single-stage frontier is baseline-dominated.)
#[test]
fn grouped_sa_cuts_bram_at_near_baseline_latency() {
    let (mut ev, space) = setup("k15mmseq", 4);
    let (base, _) = ev.eval_baselines();
    let base_lat = base.latency.unwrap();
    assert!(base.bram > 0, "k15mmseq Baseline-Max must use BRAM");

    drive(&mut *opt::by_name("grouped_sa", 11).unwrap(), &mut ev, &space, 600);
    let front = ev.pareto();
    let pts: Vec<(u64, u32)> = front.iter().map(|p| (p.latency.unwrap(), p.bram)).collect();
    let star = &front[select_highlight(&pts, 0.7, base_lat, base.bram).unwrap()];
    let lat_ratio = star.latency.unwrap() as f64 / base_lat as f64;
    let bram_ratio = star.bram as f64 / base.bram as f64;
    assert!(lat_ratio < 1.05, "highlighted point latency ratio {lat_ratio}");
    assert!(bram_ratio < 0.5, "highlighted point bram ratio {bram_ratio}");
}

/// §IV-B: FIFOAdvisor un-deadlocks designs whose Baseline-Min deadlocks,
/// finding a feasible configuration with zero BRAM overhead where one
/// exists (fig2: depth n-1 on x is still SRL-mapped).
#[test]
fn deadlocked_baseline_min_is_rescued() {
    let (mut ev, space) = setup("fig2", 1);
    let (_, min) = ev.eval_baselines();
    assert!(!min.is_feasible(), "fig2 Baseline-Min must deadlock");
    drive(&mut *opt::by_name("grouped_sa", 5).unwrap(), &mut ev, &space, 100);
    let rescue = ev
        .history
        .iter()
        .filter(|p| p.is_feasible())
        .min_by_key(|p| p.bram);
    let rescue = rescue.expect("no feasible configuration found");
    assert_eq!(rescue.bram, 0, "fig2 rescue should cost zero BRAM");
}

/// The flow of Fig. 1: all five paper optimizers produce a front; greedy
/// uses dramatically fewer samples; every front dominates-or-ties the
/// baselines it should.
#[test]
fn all_paper_optimizers_complete_on_a_real_design() {
    for mut o in opt::paper_optimizers(17) {
        let (mut ev, space) = setup("k7mmtree_balanced", 4);
        drive(&mut *o, &mut ev, &space, 150);
        assert!(
            !ev.pareto().is_empty(),
            "{} produced an empty front",
            o.name()
        );
        if o.name() == "greedy" {
            assert!(
                ev.n_evals() <= space.num_fifos() * 2 + 2,
                "greedy used {} evals",
                ev.n_evals()
            );
        }
    }
}

/// §IV-D: the PNA case study end-to-end — optimizers find feasible,
/// cheaper-than-designer configurations despite data-dependent control
/// flow, and the optimum depends on the runtime graph.
#[test]
fn flowgnn_case_study_end_to_end() {
    let (mut ev, space) = setup("flowgnn_pna", 2);
    let (base, min) = ev.eval_baselines();
    assert!(base.is_feasible());
    assert!(!min.is_feasible(), "PNA min-depth must deadlock");

    drive(&mut *opt::by_name("sa", 23).unwrap(), &mut ev, &space, 300);
    let best_feasible = ev
        .history
        .iter()
        .filter(|p| p.is_feasible())
        .min_by_key(|p| p.bram)
        .unwrap();
    assert!(
        best_feasible.bram <= base.bram,
        "optimizer should not need more BRAM than designer sizes"
    );

    // Different runtime graph → different deadlock thresholds.
    let a = bench_suite::flowgnn::pna(64, 512, 7);
    let b = bench_suite::flowgnn::pna(64, 512, 1234);
    let ta = collect_trace(&a.design, &a.args).unwrap();
    let tb = collect_trace(&b.design, &b.args).unwrap();
    let lanes = bench_suite::flowgnn::LANES;
    let bursts_a: Vec<u64> = ta.channels[..lanes].iter().map(|c| c.writes).collect();
    let bursts_b: Vec<u64> = tb.channels[..lanes].iter().map(|c| c.writes).collect();
    assert_ne!(bursts_a, bursts_b);
}

/// Multi-stimulus extension (§IV-D "future work", implemented): jointly
/// optimizing over several input graphs means a config is feasible only
/// if it deadlocks under none of them.
#[test]
fn multi_stimulus_optimization_tightens_feasibility() {
    let seeds = [7i64, 99, 1234];
    let traces: Vec<Arc<_>> = seeds
        .iter()
        .map(|&s| {
            let bd = bench_suite::flowgnn::pna(64, 512, s);
            Arc::new(collect_trace(&bd.design, &bd.args).unwrap())
        })
        .collect();
    let lanes = bench_suite::flowgnn::LANES;
    // Per-stimulus minimal msg depths.
    let per_stim: Vec<Vec<u32>> = traces
        .iter()
        .map(|t| t.channels[..lanes].iter().map(|c| c.writes as u32).collect())
        .collect();
    // A config sized for stimulus 0 only must fail on some other stimulus
    // if any lane's burst grew.
    let mut cfg0 = traces[0].baseline_min();
    for l in 0..lanes {
        cfg0[l] = per_stim[0][l];
    }
    let mut any_tighter = false;
    for (k, t) in traces.iter().enumerate().skip(1) {
        let mut sim = fifoadvisor::sim::fast::FastSim::new(t.clone());
        let out = sim.simulate(&cfg0);
        if per_stim[k].iter().zip(&per_stim[0]).any(|(b, a)| b > a) {
            assert!(
                out.is_deadlock(),
                "stimulus {k} has bigger bursts yet no deadlock"
            );
            any_tighter = true;
        }
    }
    assert!(any_tighter, "seeds chosen should produce differing bursts");

    // The joint (max-over-stimuli) sizing is feasible on all stimuli.
    let mut joint = traces[0].baseline_min();
    for l in 0..lanes {
        joint[l] = per_stim.iter().map(|p| p[l]).max().unwrap();
    }
    for t in &traces {
        let mut sim = fifoadvisor::sim::fast::FastSim::new(t.clone());
        assert!(!sim.simulate(&joint).is_deadlock());
    }
}

/// Scenario-set DSE acceptance (the multi-trace tentpole): over a
/// 4-graph FlowGNN workload, (a) a config sized optimally for one graph
/// demonstrably deadlocks on a sibling graph, (b) the workload-optimized
/// config is feasible on *every* scenario, and (c) it uses less BRAM
/// than the merged Baseline-Max.
#[test]
fn workload_sizing_is_robust_where_single_scenario_sizing_deadlocks() {
    use fifoadvisor::sim::fast::FastSim;
    use fifoadvisor::trace::workload::Workload;

    let w = Arc::new(bench_suite::build_workload("flowgnn_pna").unwrap());
    assert_eq!(w.num_scenarios(), 4);
    let lanes = bench_suite::flowgnn::LANES;

    // (a) Single-scenario "optimal": msg lanes sized exactly to graph
    // 0's bursts (minimal feasible sizing for that graph).
    let s0 = &w.scenarios()[0].trace;
    let mut cfg0 = s0.baseline_min();
    for l in 0..lanes {
        cfg0[l] = (s0.channels[l].writes as u32).max(2);
    }
    assert!(
        !FastSim::new(s0.clone()).simulate(&cfg0).is_deadlock(),
        "graph-0 sizing must be feasible on graph 0"
    );
    let deadlocked_siblings = w.scenarios()[1..]
        .iter()
        .filter(|s| FastSim::new(s.trace.clone()).simulate(&cfg0).is_deadlock())
        .count();
    assert!(
        deadlocked_siblings > 0,
        "graph-0 sizing must deadlock on some sibling graph"
    );

    // (b)+(c) Workload DSE over the scenario bank.
    let space = Space::from_workload(&w);
    let mut ev = Evaluator::for_workload(w.clone(), 2);
    let (base, min) = ev.eval_baselines();
    assert!(base.is_feasible(), "merged Baseline-Max must be robust");
    assert!(!min.is_feasible(), "Baseline-Min must deadlock somewhere");
    drive(
        &mut *opt::by_name("grouped_sa", 31).unwrap(),
        &mut ev,
        &space,
        400,
    );
    let best = ev
        .history
        .iter()
        .filter(|p| p.is_feasible())
        .min_by_key(|p| (p.bram, p.latency.unwrap()))
        .expect("workload DSE found no robust config")
        .clone();
    assert!(
        best.bram < base.bram,
        "workload sizing should beat merged Baseline-Max BRAM: {} vs {}",
        best.bram,
        base.bram
    );
    // Feasible-in-the-engine means feasible on every scenario; verify
    // independently of the engine with per-scenario cold simulators.
    for s in w.scenarios() {
        let out = FastSim::new(s.trace.clone()).simulate(&best.depths);
        assert!(
            !out.is_deadlock(),
            "workload-optimized config deadlocks on scenario '{}'",
            s.name
        );
    }
    // Sanity: Workload::single over one graph reproduces (a)'s verdict
    // through the engine path too.
    let single = Arc::new(Workload::single(s0.clone()));
    let mut ev0 = Evaluator::for_workload(single, 1);
    let (lat0, _) = ev0.eval(&cfg0);
    assert!(lat0.is_some());
    let (lat_w, _) = ev.eval(&cfg0);
    assert_eq!(lat_w, None, "graph-0 sizing must be infeasible as a workload");
}

/// The Vitis hunter baseline needs many sims and overshoots; FIFOAdvisor
/// greedy finds a strictly better (never worse) BRAM result on fig2.
#[test]
fn hunter_vs_greedy_on_fig2() {
    let (mut ev_h, space) = setup("fig2", 1);
    let cfg = opt::vitis_hunter::VitisHunter::new()
        .hunt(&mut ev_h, &space, 100)
        .unwrap();
    let hunter_bram = fifoadvisor::bram::bram_total(&cfg, &ev_h.widths);

    let (mut ev_g, space2) = setup("fig2", 1);
    drive(&mut opt::greedy::Greedy::new(), &mut ev_g, &space2, 1000);
    let greedy_best = ev_g
        .history
        .iter()
        .filter(|p| p.is_feasible())
        .map(|p| p.bram)
        .min()
        .unwrap();
    assert!(greedy_best <= hunter_bram);
}
