//! Property-based invariants of the FIFO-sizing problem and its
//! optimizers (the system-level guarantees the paper's method relies on).

use fifoadvisor::bench_suite;
use fifoadvisor::bram;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::pareto::dominates;
use fifoadvisor::opt::{self, Space};
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::SimOptions;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::prop;
use std::sync::Arc;

fn small_designs() -> Vec<&'static str> {
    vec!["fig2", "bicg", "gesummv", "flowgnn_pna", "k7mmseq_balanced"]
}

/// Growing any FIFO (under uniform read latency) never increases latency
/// and never introduces a deadlock — the fundamental monotonicity the
/// Vitis deadlock hunter and greedy reduction both exploit.
#[test]
fn property_latency_monotone_under_uniform_read_latency() {
    prop::check("latency monotone in depths", 40, |rng| {
        let name = *rng.choose(&small_designs());
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).map_err(|e| e.to_string())?);
        let mut sim = FastSim::with_options(
            t.clone(),
            SimOptions {
                uniform_read_latency: true,
            },
        );
        let ub = t.upper_bounds();
        let smaller: Vec<u32> = ub.iter().map(|&u| rng.range_u32(2, u.max(2))).collect();
        let mut bigger = smaller.clone();
        for (d, &u) in bigger.iter_mut().zip(&ub) {
            if rng.chance(0.6) {
                *d = rng.range_u32(*d, u.max(2).max(*d));
            }
        }
        let ls = sim.simulate(&smaller).latency();
        let lb = sim.simulate(&bigger).latency();
        match (ls, lb) {
            (Some(ls), Some(lb)) => {
                if lb > ls {
                    return Err(format!(
                        "{name}: bigger config slower: {lb} > {ls}\n small {smaller:?}\n big {bigger:?}"
                    ));
                }
            }
            (Some(_), None) => {
                return Err(format!("{name}: growing depths introduced deadlock"));
            }
            _ => {} // smaller deadlocked: no constraint
        }
        Ok(())
    });
}

/// Baseline-Max is deadlock-free by construction on every suite design.
#[test]
fn property_baseline_max_feasible_everywhere() {
    for name in bench_suite::all_names() {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut sim = FastSim::new(t.clone());
        assert!(
            !sim.simulate(&t.baseline_max()).is_deadlock(),
            "{name}: Baseline-Max deadlocked"
        );
    }
}

/// The BRAM model is monotone in depth; the pruned candidate sets contain
/// the depth of maximal BRAM utilization for every achievable count.
#[test]
fn property_candidates_cover_all_bram_levels() {
    prop::check("candidates cover bram levels", 60, |rng| {
        let w = 1 + rng.below(128) as u32;
        let u = 2 + rng.below(20_000) as u32;
        let cands = bram::candidate_depths(w, u);
        // Every BRAM level reachable in [2, u] appears among candidates,
        // and each candidate is the largest depth of its level.
        let mut seen = std::collections::HashSet::new();
        for &c in &cands {
            seen.insert(bram::bram_for_fifo(c, w));
            if c < u {
                let next = bram::bram_for_fifo(c + 1, w);
                if bram::bram_for_fifo(c, w) >= next && c > 2 {
                    return Err(format!("candidate {c} (w={w}) not a plateau end"));
                }
            }
        }
        for probe in [2u32, 3, u / 2, u.saturating_sub(1).max(2), u] {
            if probe <= u && !seen.contains(&bram::bram_for_fifo(probe, w)) {
                return Err(format!(
                    "bram level of depth {probe} (w={w}, u={u}) unreachable from candidates"
                ));
            }
        }
        Ok(())
    });
}

/// Every optimizer's reported front is internally non-dominated, and all
/// of its feasible evaluations are covered by the front.
#[test]
fn property_fronts_are_sound() {
    for opt_name in ["random", "grouped_random", "sa", "grouped_sa", "greedy"] {
        for design in ["fig2", "gesummv", "flowgnn_pna"] {
            let bd = bench_suite::build(design);
            let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
            let space = Space::from_trace(&t);
            let mut ev = Evaluator::new(t);
            let mut o = opt::by_name(opt_name, 7).unwrap();
            drive(&mut *o, &mut ev, &space, 120);
            let front = ev.pareto();
            for a in &front {
                for b in &front {
                    let pa = (a.latency.unwrap(), a.bram);
                    let pb = (b.latency.unwrap(), b.bram);
                    assert!(
                        !dominates(pa, pb) || pa == pb,
                        "{opt_name}/{design}: dominated front member"
                    );
                }
            }
            for p in ev.history.iter().filter(|p| p.is_feasible()) {
                let pp = (p.latency.unwrap(), p.bram);
                assert!(
                    front.iter().any(|m| {
                        let pm = (m.latency.unwrap(), m.bram);
                        pm == pp || dominates(pm, pp)
                    }),
                    "{opt_name}/{design}: history point not covered by front"
                );
            }
        }
    }
}

/// Fault injection: the evaluator must classify deadlocks consistently —
/// a deadlocked configuration stays deadlocked on re-evaluation (memo or
/// not), and never reports a latency.
#[test]
fn property_deadlock_classification_is_stable() {
    prop::check("deadlock stability", 30, |rng| {
        let bd = bench_suite::build("fig2");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).map_err(|e| e.to_string())?);
        let mut ev = Evaluator::new(t.clone());
        let ub = t.upper_bounds();
        let cfg: Vec<u32> = ub.iter().map(|&u| rng.range_u32(2, u.max(2))).collect();
        let (l1, b1) = ev.eval(&cfg);
        ev.reset_run(true);
        let (l2, b2) = ev.eval(&cfg);
        if (l1, b1) != (l2, b2) {
            return Err(format!("unstable evaluation: {l1:?}/{b1} vs {l2:?}/{b2}"));
        }
        Ok(())
    });
}

/// Grouped optimizers only ever propose group-uniform configurations
/// (modulo per-member bound clamping) — the structural constraint that
/// makes them sample-efficient.
#[test]
fn property_grouped_configs_are_uniform() {
    let bd = bench_suite::build("mvt");
    let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
    let space = Space::from_trace(&t);
    let mut ev = Evaluator::new(t);
    drive(&mut *opt::by_name("grouped_random", 3).unwrap(), &mut ev, &space, 40);
    drive(&mut *opt::by_name("grouped_sa", 3).unwrap(), &mut ev, &space, 40);
    for p in &ev.history {
        for ids in &space.groups {
            let mx = ids.iter().map(|&i| p.depths[i]).max().unwrap();
            for &i in ids {
                assert!(p.depths[i] == mx || p.depths[i] == space.bounds[i].max(2));
            }
        }
    }
}

/// Randomized cross-check of the whole evaluation pipeline against a
/// from-scratch recomputation (fresh evaluator, fresh simulator).
#[test]
fn property_pipeline_reproducible() {
    prop::check("pipeline reproducible", 10, |rng| {
        let name = *rng.choose(&small_designs());
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).map_err(|e| e.to_string())?);
        let space = Space::from_trace(&t);
        let seed = rng.next_u64();
        let run = |threads: usize| {
            let mut ev = Evaluator::parallel(t.clone(), threads);
            drive(&mut opt::random::RandomSearch::new(seed, false), &mut ev, &space, 64);
            ev.history
                .iter()
                .map(|p| (p.depths.clone(), p.latency, p.bram))
                .collect::<Vec<_>>()
        };
        if run(1) != run(4) {
            return Err(format!("{name}: parallel run diverged from serial"));
        }
        Ok(())
    });
}
