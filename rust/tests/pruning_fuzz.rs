//! Differential fuzzing of the simulation-free pruning layer
//! (`opt::dominance`): randomized depth walks on every suite design
//! asserting that
//!
//! - the [`FeasibilityOracle`] never contradicts a real
//!   `FastSim`/`ScenarioSim` run in **either** verdict direction
//!   (`Infeasible` ⇒ the simulator deadlocks, `Feasible` ⇒ it doesn't),
//! - clamp-canonicalized configurations are outcome-identical to their
//!   raw counterparts (full [`SimOutcome`] equality — latency *and*
//!   blocked sets — plus per-scenario latencies on workloads), and
//! - deadlock is monotone in depths under fuzzed configurations
//!   (shrinking depths never rescues a deadlock).
//!
//! Walk configurations deliberately overshoot the DSE upper bounds so the
//! clamp region above the observed write counts is exercised even on
//! designs without designer depth hints (the shared
//! `util::prop::random_depths` generator).

use fifoadvisor::bench_suite;
use fifoadvisor::opt::dominance::{Canonicalizer, FeasibilityOracle, OracleVerdict};
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::ScenarioSim;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::trace::Trace;
use fifoadvisor::util::prop::{
    random_depths as random_cfg, suite_with_specials as all_with_specials,
};
use fifoadvisor::util::Rng;
use std::sync::Arc;

fn trace_of(name: &str) -> Arc<Trace> {
    let bd = bench_suite::build(name);
    Arc::new(collect_trace(&bd.design, &bd.args).unwrap())
}

#[test]
fn oracle_never_contradicts_the_simulator_on_any_design() {
    for name in all_with_specials() {
        let t = trace_of(name);
        let mut sim = FastSim::new(t.clone());
        let mut oracle = FeasibilityOracle::for_trace(&t);
        let ub = t.upper_bounds();
        let mut rng = Rng::new(0x0DAC1E ^ name.len() as u64);
        for step in 0..14 {
            let cfg = random_cfg(&mut rng, &ub, 9);
            let predicted = oracle.classify(&cfg);
            let out = sim.simulate(&cfg);
            match predicted {
                Some(OracleVerdict::Infeasible) => {
                    assert!(
                        out.is_deadlock(),
                        "{name} step {step}: oracle said Infeasible but {cfg:?} runs"
                    );
                }
                Some(OracleVerdict::Feasible { .. }) => {
                    assert!(
                        !out.is_deadlock(),
                        "{name} step {step}: oracle said Feasible but {cfg:?} deadlocks"
                    );
                }
                None => {}
            }
            oracle.note(&cfg, out.latency());
            // What was just learned must classify consistently too.
            match oracle.classify(&cfg) {
                Some(OracleVerdict::Infeasible) => assert!(out.is_deadlock(), "{name}"),
                Some(OracleVerdict::Feasible { .. }) => assert!(!out.is_deadlock(), "{name}"),
                None => panic!("{name}: a just-learned config must classify"),
            }
        }
    }
}

#[test]
fn clamp_canonical_configs_are_outcome_identical_on_every_design() {
    for name in all_with_specials() {
        let t = trace_of(name);
        let canon = Canonicalizer::for_trace(&t);
        let mut raw_sim = FastSim::new(t.clone());
        let mut canon_sim = FastSim::new(t.clone());
        let ub = t.upper_bounds();
        let mut rng = Rng::new(0xC1A4 ^ name.len() as u64);
        let mut clamped = 0usize;
        for step in 0..12 {
            let cfg = random_cfg(&mut rng, &ub, 17);
            if let Some(ccfg) = canon.canonical(&cfg) {
                clamped += 1;
                let raw_out = raw_sim.simulate(&cfg);
                let canon_out = canon_sim.simulate(&ccfg);
                assert_eq!(
                    raw_out, canon_out,
                    "{name} step {step}: clamp changed the outcome, raw {cfg:?} vs canon {ccfg:?}"
                );
                // Canonicalization is idempotent.
                assert!(canon.canonical(&ccfg).is_none(), "{name}: not idempotent");
            }
        }
        assert!(
            clamped > 0,
            "{name}: padded walk never reached the clamp region"
        );
    }
}

#[test]
fn deadlock_is_monotone_under_fuzzed_configs() {
    for name in all_with_specials() {
        let t = trace_of(name);
        let mut sim = FastSim::new(t.clone());
        let ub = t.upper_bounds();
        let mut rng = Rng::new(0x3030 ^ name.len() as u64);
        for step in 0..10 {
            let big = random_cfg(&mut rng, &ub, 3);
            // Component-wise shrink of `big`.
            let small: Vec<u32> = big.iter().map(|&d| rng.range_u32(1, d)).collect();
            let big_dead = sim.simulate(&big).is_deadlock();
            let small_dead = sim.simulate(&small).is_deadlock();
            assert!(
                !big_dead || small_dead,
                "{name} step {step}: shrinking {big:?} → {small:?} rescued a deadlock"
            );
        }
    }
}

#[test]
fn oracle_and_clamp_hold_on_multi_scenario_banks() {
    for wname in ["fig2", "flowgnn_pna"] {
        let w = Arc::new(bench_suite::build_workload(wname).unwrap());
        assert!(w.num_scenarios() > 1, "{wname} should be multi-scenario");
        let canon = Canonicalizer::for_workload(&w);
        let mut oracle = FeasibilityOracle::for_workload(&w);
        let mut bank = ScenarioSim::new(&w);
        let mut ref_bank = ScenarioSim::new(&w);
        let mut canon_bank = ScenarioSim::new(&w);
        let ub = w.upper_bounds();
        let mut rng = Rng::new(0xBA41 ^ wname.len() as u64);
        for step in 0..12 {
            let cfg = random_cfg(&mut rng, &ub, 5);
            // The engine's early-exit latency path agrees with the full
            // simulate path on every verdict and latency.
            let fast = bank.eval_latency(&cfg, true);
            let full = ref_bank.simulate(&cfg).latency();
            assert_eq!(fast, full, "{wname} step {step}: early-exit diverged {cfg:?}");
            // Oracle consistency against the aggregate verdict.
            match oracle.classify(&cfg) {
                Some(OracleVerdict::Infeasible) => {
                    assert!(full.is_none(), "{wname} step {step}: bad Infeasible {cfg:?}")
                }
                Some(OracleVerdict::Feasible { .. }) => {
                    assert!(full.is_some(), "{wname} step {step}: bad Feasible {cfg:?}")
                }
                None => {}
            }
            oracle.note(&cfg, full);
            // Clamp preserves per-scenario outcomes, not just the
            // aggregate.
            if let Some(ccfg) = canon.canonical(&cfg) {
                let canon_full = canon_bank.simulate(&ccfg).latency();
                assert_eq!(full, canon_full, "{wname} step {step}: clamp diverged");
                assert_eq!(
                    ref_bank.scenario_latencies(),
                    canon_bank.scenario_latencies(),
                    "{wname} step {step}: per-scenario latencies diverged"
                );
            }
        }
    }
}
