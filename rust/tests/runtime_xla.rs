//! Integration tests for the batched-analytics runtime path: the module
//! (native interpreter of the exported JAX/Pallas computation; see
//! `rust/src/runtime`) must agree exactly with the native Rust
//! implementations (Algorithm 1 BRAM model, weighted objectives, Pareto
//! dominance) at every bucket shape.

use fifoadvisor::bench_suite;
use fifoadvisor::bram;
use fifoadvisor::dse::Evaluator;
use fifoadvisor::opt::pareto::ObjPoint;
use fifoadvisor::runtime::{BatchAnalytics, XlaBram};
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::Rng;
use std::sync::Arc;

fn analytics() -> BatchAnalytics {
    BatchAnalytics::load_default().expect("analytics module must load without artifacts")
}

#[test]
fn xla_bram_matches_native_on_random_batches() {
    let mut a = analytics();
    let mut rng = Rng::new(42);
    for &f in &[5usize, 64, 200, 848] {
        let widths: Vec<u32> = (0..f).map(|_| rng.range_u32(1, 128)).collect();
        let configs: Vec<Box<[u32]>> = (0..100)
            .map(|_| {
                (0..f)
                    .map(|_| rng.range_u32(2, 20_000))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        let lats: Vec<Option<u64>> = (0..configs.len())
            .map(|i| {
                if i % 7 == 0 {
                    None
                } else {
                    Some(rng.below(1_000_000))
                }
            })
            .collect();
        let betas: Vec<f64> = (0..a.betas)
            .map(|i| i as f64 / (a.betas - 1) as f64)
            .collect();
        let out = a.evaluate(&configs, &widths, &lats, &betas).unwrap();
        for (i, cfg) in configs.iter().enumerate() {
            assert_eq!(
                out.bram_totals[i],
                bram::bram_total(cfg, &widths),
                "bram mismatch at config {i} (f={f})"
            );
        }
        // Weighted objectives match the native formula (f32 tolerance).
        for (k, &beta) in betas.iter().enumerate() {
            for (i, l) in lats.iter().enumerate() {
                let native = match l {
                    Some(l) => {
                        fifoadvisor::opt::objective::weighted(beta, *l, out.bram_totals[i])
                    }
                    None => f64::INFINITY,
                };
                let xla = out.scores[k][i];
                if native.is_finite() {
                    let tol = native.abs().max(1.0) * 1e-4;
                    assert!(
                        (native - xla).abs() <= tol,
                        "score mismatch k={k} i={i}: {native} vs {xla}"
                    );
                } else {
                    assert!(!xla.is_finite() || xla > 1e30);
                }
            }
        }
        // Dominance mask matches the exported kernel formula
        // (python/compile/kernels/pareto.py): lat_j <= lat_i &&
        // bram_j <= bram_i with one strict inequality, deadlocks
        // encoded as lat = +inf.
        let enc: Vec<(f64, u32)> = lats
            .iter()
            .enumerate()
            .map(|(i, l)| {
                (
                    l.map(|l| l as f64).unwrap_or(f64::INFINITY),
                    out.bram_totals[i],
                )
            })
            .collect();
        for (i, &(li, bi)) in enc.iter().enumerate() {
            let native_dom = enc
                .iter()
                .any(|&(lj, bj)| lj <= li && bj <= bi && (lj < li || bj < bi));
            assert_eq!(out.dominated[i], native_dom, "dominance mismatch at {i}");
        }
    }
}

#[test]
fn evaluator_with_xla_backend_matches_native_evaluator() {
    let bd = bench_suite::build("gesummv");
    let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
    let mut native = Evaluator::new(t.clone());
    let mut xla = Evaluator::with_backend(t.clone(), Box::new(XlaBram::new(analytics())), 2);
    assert_eq!(xla.backend_name(), "analytics");

    let mut rng = Rng::new(9);
    let ub = t.upper_bounds();
    let configs: Vec<Box<[u32]>> = (0..50)
        .map(|_| {
            ub.iter()
                .map(|&u| rng.range_u32(2, u.max(2)))
                .collect::<Box<[u32]>>()
        })
        .collect();
    assert_eq!(native.eval_batch(&configs), xla.eval_batch(&configs));
}

#[test]
fn oversize_fifo_count_is_rejected() {
    let mut a = analytics();
    let max = a.max_fifos();
    let widths = vec![32u32; max + 1];
    let configs: Vec<Box<[u32]>> = vec![vec![2u32; max + 1].into()];
    let betas: Vec<f64> = (0..a.betas).map(|i| i as f64).collect();
    let err = a.evaluate(&configs, &widths, &[Some(1)], &betas);
    assert!(err.is_err());
}

#[test]
fn pareto_front_from_xla_mask_matches_sweep() {
    // End-to-end: use the dominance mask to extract a front and compare
    // with the native sweep implementation.
    let mut a = analytics();
    let mut rng = Rng::new(77);
    let f = 10usize;
    let widths: Vec<u32> = (0..f).map(|_| 32).collect();
    let configs: Vec<Box<[u32]>> = (0..128)
        .map(|_| {
            (0..f)
                .map(|_| rng.range_u32(2, 4096))
                .collect::<Box<[u32]>>()
        })
        .collect();
    let lats: Vec<Option<u64>> = (0..configs.len())
        .map(|_| Some(rng.below(10_000)))
        .collect();
    let betas: Vec<f64> = (0..a.betas)
        .map(|i| i as f64 / (a.betas - 1) as f64)
        .collect();
    let out = a.evaluate(&configs, &widths, &lats, &betas).unwrap();

    let pts: Vec<ObjPoint> = lats
        .iter()
        .enumerate()
        .map(|(i, l)| ObjPoint {
            latency: l.unwrap(),
            bram: out.bram_totals[i],
            index: i,
        })
        .collect();
    let front = fifoadvisor::opt::pareto::pareto_front(&pts);
    for m in &front {
        assert!(!out.dominated[m.index], "front member flagged dominated");
    }
    for (i, &d) in out.dominated.iter().enumerate() {
        if !d {
            assert!(
                front
                    .iter()
                    .any(|m| m.latency == pts[i].latency && m.bram == pts[i].bram),
                "undominated point {i} missing from front"
            );
        }
    }
}
