//! The strongest correctness signal in the repo: the fast event-driven
//! simulator and the golden cycle-stepped simulator are structurally
//! independent implementations of the same semantics — they must agree
//! exactly (latency and deadlock verdicts) on every design in the suite
//! and on randomized designs/configurations.

use fifoadvisor::bench_suite;
use fifoadvisor::ir::{DesignBuilder, Expr};
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::golden::simulate_golden;
use fifoadvisor::sim::SimOptions;
use fifoadvisor::trace::{collect_trace, Trace};
use fifoadvisor::util::{prop, Rng};
use std::sync::Arc;

fn random_config(rng: &mut Rng, trace: &Trace) -> Vec<u32> {
    trace
        .upper_bounds()
        .iter()
        .map(|&u| {
            // Mix corner cases and interior points.
            match rng.below(4) {
                0 => 2,
                1 => u.max(2),
                _ => rng.range_u32(2, u.max(2)),
            }
        })
        .collect()
}

#[test]
fn suite_designs_agree_on_random_configs() {
    // The largest designs are exercised once in the table2 bench; here we
    // cover the smaller ones with multiple random configurations.
    let names = [
        "fig2",
        "bicg",
        "gesummv",
        "mvt",
        "flowgnn_pna",
        "k7mmseq_balanced",
        "k15mmseq_imbalanced",
    ];
    let mut rng = Rng::new(2024);
    for name in names {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut fast = FastSim::new(t.clone());
        for trial in 0..8 {
            let cfg = random_config(&mut rng, &t);
            let f = fast.simulate(&cfg).latency();
            let g = simulate_golden(&t, &cfg, SimOptions::default()).latency();
            assert_eq!(f, g, "{name} trial {trial} cfg {cfg:?}");
        }
    }
}

/// Generate a random dataflow design: a random DAG of processes passing
/// random token counts, with random delays — adversarial input for both
/// simulators.
fn random_design(rng: &mut Rng) -> (fifoadvisor::ir::Design, Vec<i64>) {
    let n_stages = 2 + rng.index(4);
    let mut b = DesignBuilder::new("rand", 0);
    let mut prev: Option<(Vec<usize>, u64)> = None; // (chans, tokens)
    for s in 0..n_stages {
        let width = *rng.choose(&[8u32, 32, 64, 512]);
        let fanout = 1 + rng.index(3);
        let tokens = 1 + rng.below(24);
        let chans: Vec<usize> = (0..fanout)
            .map(|i| b.channel(&format!("c{s}_{i}"), width))
            .collect();
        let delay_in = rng.below(4) as u32;
        let delay_out = rng.below(4) as u32;
        match prev.clone() {
            None => {
                let cc = chans.clone();
                b.process(&format!("src{s}"), move |p| {
                    p.for_n(tokens, |p, _| {
                        for &c in &cc {
                            p.delay(delay_out);
                            p.write(c, Expr::c(1));
                        }
                    });
                });
            }
            Some((inputs, in_tokens)) => {
                // A relay stage: reads all inputs, writes all outputs.
                // Token counts must match: read in_tokens from each input,
                // write `tokens` to each output.
                let cc = chans.clone();
                let ins = inputs.clone();
                b.process(&format!("stage{s}"), move |p| {
                    p.for_n(in_tokens, |p, _| {
                        for &c in &ins {
                            p.delay(delay_in);
                            let _ = p.read(c);
                        }
                    });
                    p.for_n(tokens, |p, _| {
                        for &c in &cc {
                            p.delay(delay_out);
                            p.write(c, Expr::c(1));
                        }
                    });
                });
            }
        }
        prev = Some((chans, tokens));
    }
    // Final sink.
    let (inputs, in_tokens) = prev.unwrap();
    b.process("sink", move |p| {
        p.for_n(in_tokens, |p, _| {
            for &c in &inputs {
                let _ = p.read(c);
            }
        });
    });
    (b.build(), vec![])
}

#[test]
fn property_random_designs_agree() {
    prop::check("fast == golden on random designs", 60, |rng| {
        let (design, args) = random_design(rng);
        let t = Arc::new(collect_trace(&design, &args).map_err(|e| e.to_string())?);
        let mut fast = FastSim::new(t.clone());
        for _ in 0..4 {
            let cfg = random_config(rng, &t);
            let f = fast.simulate(&cfg).latency();
            let g = simulate_golden(&t, &cfg, SimOptions::default()).latency();
            if f != g {
                return Err(format!("mismatch: fast {f:?} golden {g:?} cfg {cfg:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn property_uniform_latency_agrees_too() {
    let opts = SimOptions {
        uniform_read_latency: true,
    };
    prop::check("fast == golden (uniform read latency)", 30, |rng| {
        let (design, args) = random_design(rng);
        let t = Arc::new(collect_trace(&design, &args).map_err(|e| e.to_string())?);
        let mut fast = FastSim::with_options(t.clone(), opts);
        let cfg = random_config(rng, &t);
        let f = fast.simulate(&cfg).latency();
        let g = simulate_golden(&t, &cfg, opts).latency();
        if f != g {
            return Err(format!("mismatch: {f:?} vs {g:?}"));
        }
        Ok(())
    });
}
