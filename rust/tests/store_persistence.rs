//! Cross-run store contract, end to end through the public API:
//!
//! - **Replay**: a second identical optimize against the same cache dir
//!   performs *zero* simulations — in the same process or across a
//!   simulated restart (fresh engine, same directory) — and its
//!   history/front is bit-identical to the cold run's, serial and
//!   `--jobs N` alike.
//! - **Corruption robustness**: truncating or garbling a snapshot file
//!   at any offset never panics and never changes a verdict — a
//!   damaged snapshot is rejected wholesale and the run degrades to a
//!   cold start that produces the same results.

use fifoadvisor::bench_suite;
use fifoadvisor::dse::{drive, EvalEngine};
use fifoadvisor::opt::{self, Space};
use fifoadvisor::store::{Snapshot, Store};
use fifoadvisor::util::Rng;
use fifoadvisor::Workload;
use std::sync::Arc;

fn tmpdir(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("fifoadvisor_store_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d.to_string_lossy().into_owned()
}

fn fig2_workload() -> Arc<Workload> {
    let bd = bench_suite::try_build("fig2").unwrap();
    Arc::new(Workload::from_design_args(&bd.design, &[vec![16]]).unwrap())
}

/// A run history reduced to its deterministic fields.
type Hist = Vec<(Vec<u32>, Option<u64>, u32)>;

/// One full "optimize" pass the way the CLI runs it: warm-start from
/// the store when a snapshot is present, baselines, reset, drive.
/// Returns the run history (deterministic fields only) and the total
/// simulation count, plus the engine for capturing a snapshot.
fn optimize_once(
    w: &Arc<Workload>,
    jobs: usize,
    store: Option<(&Store, &str)>,
) -> (Hist, u64, EvalEngine) {
    let mut ev = EvalEngine::for_workload(w.clone(), jobs);
    if let Some((st, key)) = store {
        if let Some(snap) = st.load(key) {
            snap.apply(&mut ev).expect("a loaded snapshot must apply");
        }
    }
    let space = Space::from_workload(w);
    ev.eval_baselines();
    ev.reset_run(false);
    let mut o = opt::by_name("grouped_sa", 11).unwrap();
    drive(&mut *o, &mut ev, &space, 120);
    let hist = ev
        .history
        .iter()
        .map(|p| (p.depths.to_vec(), p.latency, p.bram))
        .collect();
    let sims = ev.n_sim;
    (hist, sims, ev)
}

#[test]
fn replay_across_a_restart_is_zero_sims_and_bit_identical() {
    let dir = tmpdir("replay");
    let w = fig2_workload();
    let store = Store::new(&dir, 64);
    let key = Store::key("fig2", &w, "fast", true, true);

    // Cold run: simulates, then persists its snapshot.
    let (cold_hist, cold_sims, ev) = optimize_once(&w, 1, Some((&store, &key)));
    assert!(cold_sims > 0, "cold run must simulate");
    store.save(&key, &Snapshot::capture("fig2", &ev)).unwrap();
    drop(ev);

    // "Restart" #1: a brand-new serial engine over the same directory.
    let (warm_hist, warm_sims, _) = optimize_once(&w, 1, Some((&store, &key)));
    assert_eq!(warm_sims, 0, "warm replay must not simulate");
    assert_eq!(warm_hist, cold_hist, "warm history must be bit-identical");

    // "Restart" #2: same thing with a worker pool (--jobs 4).
    let (par_hist, par_sims, _) = optimize_once(&w, 4, Some((&store, &key)));
    assert_eq!(par_sims, 0, "parallel warm replay must not simulate");
    assert_eq!(par_hist, cold_hist, "serial/parallel warm runs must agree");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_snapshot_files_never_panic_and_never_change_a_verdict() {
    let dir = tmpdir("fuzz");
    let w = fig2_workload();
    let store = Store::new(&dir, 64);
    let key = Store::key("fig2", &w, "fast", true, true);

    let (cold_hist, _, ev) = optimize_once(&w, 1, None);
    store.save(&key, &Snapshot::capture("fig2", &ev)).unwrap();
    let canonical = Snapshot::capture("fig2", &ev).to_json().to_string_compact();
    drop(ev);
    let path = store.dir().join(format!("{key}.json"));
    let pristine = std::fs::read(&path).unwrap();
    assert!(!pristine.is_empty());

    let mut rng = Rng::new(0xF00D);
    let mut rejected = 0usize;
    for case in 0..48 {
        let mut bytes = pristine.clone();
        match rng.below(3) {
            // Torn write: the file ends mid-record.
            0 => bytes.truncate(rng.index(bytes.len())),
            // Bit rot: one flipped bit anywhere.
            1 => {
                let i = rng.index(bytes.len());
                bytes[i] ^= 1u8 << rng.index(8);
            }
            // Overwrite: one byte replaced with arbitrary printable junk.
            _ => {
                let i = rng.index(bytes.len());
                bytes[i] = rng.range_u32(32, 127) as u8;
            }
        }
        if bytes == pristine {
            continue; // the mutation was a no-op (e.g. same byte drawn)
        }
        std::fs::write(&path, &bytes).unwrap();

        // Load must not panic; if it accepts the file, the checksum
        // guarantees the content is byte-equal to what was saved.
        match store.load(&key) {
            None => rejected += 1,
            Some(snap) => assert_eq!(
                snap.to_json().to_string_compact(),
                canonical,
                "case {case}: an accepted snapshot must match the saved one"
            ),
        }

        // Whatever happened above, a run against this store produces
        // exactly the cold results (worst case it just re-simulates).
        let (hist, _, _) = optimize_once(&w, 1, Some((&store, &key)));
        assert_eq!(hist, cold_hist, "case {case}: corruption changed a verdict");
    }
    assert!(rejected > 0, "the fuzz never produced a rejected file");

    // Restoring the pristine bytes restores the warm path.
    std::fs::write(&path, &pristine).unwrap();
    let (hist, sims, _) = optimize_once(&w, 1, Some((&store, &key)));
    assert_eq!(sims, 0);
    assert_eq!(hist, cold_hist);

    let _ = std::fs::remove_dir_all(&dir);
}
