//! Fault-tolerance contract of the sweep orchestrator: an interrupted
//! grid resumed from its manifest is indistinguishable from an
//! uninterrupted one (bit-identical aggregates, untouched record
//! files), shards partition the grid deterministically and merge
//! cleanly, a panicking cell fails in the manifest without taking its
//! siblings down, and budget-exhausted cells land as done-but-truncated.

use fifoadvisor::dse::sweep::{run_sweep_with, CellStatus, Manifest, SweepConfig, SweepHooks};
use fifoadvisor::util::Json;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn tmpdir(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("fifoadvisor_sweep_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.to_string_lossy().into_owned()
}

/// 2 designs × 1 optimizer × 2 seeds = 4 cells, small budget.
fn base_cfg(out_dir: &str) -> SweepConfig {
    let j = Json::parse(
        r#"{"designs": ["fig2", "gesummv"], "optimizers": ["greedy"],
            "budget": 60, "seeds": [1, 2], "jobs": 1}"#,
    )
    .unwrap();
    let mut cfg = SweepConfig::from_json(&j).unwrap();
    cfg.out_dir = Some(out_dir.to_string());
    cfg
}

/// Per-cell record files (everything but manifests/aggregates), with
/// their exact bytes, sorted by path.
fn record_files(dir: &str) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| {
            n.ends_with(".json") && !n.starts_with("manifest") && !n.starts_with("aggregate")
        })
        .map(|n| {
            let p = format!("{dir}/{n}");
            let bytes = std::fs::read(&p).unwrap();
            (p, bytes)
        })
        .collect();
    out.sort();
    out
}

#[test]
fn interrupted_then_resumed_matches_uninterrupted() {
    let full_dir = tmpdir("full");
    let res_dir = tmpdir("resumed");

    let full = run_sweep_with(&base_cfg(&full_dir), &SweepHooks::default()).unwrap();
    assert_eq!(full.rows.len(), 4);
    assert!(!full.stopped_early);
    assert!(full.failed.is_empty());

    // "Crash" after two cells: the runner stops claiming work, leaving
    // two done cells checkpointed and two pending in the manifest.
    let hooks = SweepHooks {
        stop_after_cells: Some(2),
        ..Default::default()
    };
    let cut = run_sweep_with(&base_cfg(&res_dir), &hooks).unwrap();
    assert!(cut.stopped_early);
    assert_eq!(cut.rows.len(), 2);
    assert!(
        !Path::new(&format!("{res_dir}/aggregate.csv")).exists(),
        "a partial run must not write aggregates"
    );
    let manifest = Manifest::load(&format!("{res_dir}/manifest.json")).unwrap();
    let done = manifest
        .cells
        .values()
        .filter(|e| matches!(e.status, CellStatus::Done { .. }))
        .count();
    assert_eq!(done, 2);
    let checkpointed = record_files(&res_dir);
    assert_eq!(checkpointed.len(), 2);

    let mut cfg = base_cfg(&res_dir);
    cfg.resume = true;
    let resumed = run_sweep_with(&cfg, &SweepHooks::default()).unwrap();
    assert_eq!(resumed.resumed, 2, "both done cells must be skipped");
    assert_eq!(resumed.rows.len(), 4);
    assert!(resumed.failed.is_empty());
    assert!(!resumed.stopped_early);

    // Skipped cells' record files survive the resume byte-for-byte.
    for (path, before) in &checkpointed {
        assert_eq!(&std::fs::read(path).unwrap(), before, "{path} rewritten");
    }
    // The deterministic aggregates are bit-identical to the
    // uninterrupted run's.
    for f in ["aggregate.csv", "aggregate.json"] {
        let a = std::fs::read(format!("{full_dir}/{f}")).unwrap();
        let b = std::fs::read(format!("{res_dir}/{f}")).unwrap();
        assert_eq!(a, b, "{f} differs between full and resumed runs");
    }

    let _ = std::fs::remove_dir_all(&full_dir);
    let _ = std::fs::remove_dir_all(&res_dir);
}

#[test]
fn shards_partition_the_grid_and_merge_cleanly() {
    let dir = tmpdir("shards");
    let full_dir = tmpdir("shards_full");

    let full = run_sweep_with(&base_cfg(&full_dir), &SweepHooks::default()).unwrap();
    assert_eq!(full.rows.len(), 4);

    // Run both shards into ONE out-dir, as a CI matrix would.
    let mut union = std::collections::BTreeSet::new();
    let mut total = 0;
    for i in 0..2 {
        let mut cfg = base_cfg(&dir);
        cfg.shard = Some((i, 2));
        let out = run_sweep_with(&cfg, &SweepHooks::default()).unwrap();
        assert!(out.failed.is_empty());
        total += out.rows.len();
        let m = Manifest::load(&format!("{dir}/manifest.shard-{i}-of-2.json")).unwrap();
        for (k, e) in &m.cells {
            assert!(
                matches!(e.status, CellStatus::Done { .. }),
                "shard {i} left {k} unfinished"
            );
            assert!(union.insert(k.clone()), "cell {k} ran in both shards");
        }
    }
    assert_eq!(total, 4, "shards must cover the whole grid");
    assert_eq!(union.len(), 4, "shard union must equal the full grid");
    assert!(
        !Path::new(&format!("{dir}/aggregate.csv")).exists(),
        "sharded invocations must leave aggregation to the merge pass"
    );

    // Final unsharded resume over the merged dir: re-runs nothing and
    // emits aggregates identical to an uninterrupted single-machine run.
    let ran = Arc::new(AtomicUsize::new(0));
    let ran_in_hook = ran.clone();
    let hooks = SweepHooks {
        on_cell_start: Some(Box::new(move |_, _| {
            ran_in_hook.fetch_add(1, Ordering::SeqCst);
        })),
        stop_after_cells: None,
    };
    let mut cfg = base_cfg(&dir);
    cfg.resume = true;
    let merged = run_sweep_with(&cfg, &hooks).unwrap();
    assert_eq!(merged.resumed, 4);
    assert_eq!(ran.load(Ordering::SeqCst), 0, "merge pass re-ran a cell");
    assert_eq!(merged.rows.len(), 4);
    for f in ["aggregate.csv", "aggregate.json"] {
        let a = std::fs::read(format!("{full_dir}/{f}")).unwrap();
        let b = std::fs::read(format!("{dir}/{f}")).unwrap();
        assert_eq!(a, b, "{f} differs between full and shard-merged runs");
    }

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&full_dir);
}

#[test]
fn panicking_cells_fail_in_manifest_without_aborting_siblings() {
    let clean_dir = tmpdir("panic_clean");
    let clean = run_sweep_with(&base_cfg(&clean_dir), &SweepHooks::default()).unwrap();

    // Every gesummv cell panics; fig2 cells must be unaffected.
    let dir = tmpdir("panic");
    let mut cfg = base_cfg(&dir);
    cfg.max_retries = 0;
    let hooks = SweepHooks {
        on_cell_start: Some(Box::new(|cell, _attempt| {
            if cell.design.name == "gesummv" {
                panic!("injected fault");
            }
        })),
        stop_after_cells: None,
    };
    let out = run_sweep_with(&cfg, &hooks).unwrap();
    assert_eq!(out.failed.len(), 2);
    assert_eq!(out.rows.len(), 2);
    for f in &out.failed {
        assert_eq!(f.design, "gesummv");
        assert_eq!(f.attempts, 1, "max_retries 0 means one attempt");
        assert!(f.reason.contains("injected fault"), "{}", f.reason);
    }
    let m = Manifest::load(&format!("{dir}/manifest.json")).unwrap();
    let failed = m
        .cells
        .values()
        .filter(|e| {
            matches!(&e.status, CellStatus::Failed { reason } if reason.contains("injected fault"))
        })
        .count();
    assert_eq!(failed, 2, "both faults must be recorded in the manifest");
    let fig2_clean: Vec<_> = clean.rows.iter().filter(|r| r.design == "fig2").collect();
    let fig2_hurt: Vec<_> = out.rows.iter().filter(|r| r.design == "fig2").collect();
    assert_eq!(fig2_clean.len(), fig2_hurt.len());
    for (a, b) in fig2_clean.iter().zip(&fig2_hurt) {
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.star_latency, b.star_latency);
        assert_eq!(a.star_bram, b.star_bram);
        assert_eq!(a.sims, b.sims, "sibling cells must be bit-identical");
    }

    // A transient fault (first attempt only) is absorbed by one retry,
    // and the retried result is still deterministic.
    let flaky_dir = tmpdir("panic_retry");
    let mut cfg = base_cfg(&flaky_dir);
    cfg.max_retries = 1;
    cfg.retry_backoff_ms = 1;
    let hooks = SweepHooks {
        on_cell_start: Some(Box::new(|cell, attempt| {
            if cell.design.name == "gesummv" && attempt == 1 {
                panic!("transient fault");
            }
        })),
        stop_after_cells: None,
    };
    let retried = run_sweep_with(&cfg, &hooks).unwrap();
    assert!(retried.failed.is_empty(), "one retry must absorb the fault");
    assert_eq!(retried.rows.len(), 4);
    let m = Manifest::load(&format!("{flaky_dir}/manifest.json")).unwrap();
    for e in m.cells.values() {
        let expected = if e.design == "gesummv" { 2 } else { 1 };
        assert_eq!(e.attempts, expected, "{}/s{}", e.design, e.seed);
    }
    let ges_clean: Vec<_> = clean.rows.iter().filter(|r| r.design == "gesummv").collect();
    let ges_retried: Vec<_> = retried
        .rows
        .iter()
        .filter(|r| r.design == "gesummv")
        .collect();
    for (a, b) in ges_clean.iter().zip(&ges_retried) {
        assert_eq!(a.star_latency, b.star_latency);
        assert_eq!(a.sims, b.sims, "retried cells must be bit-identical");
    }

    let _ = std::fs::remove_dir_all(&clean_dir);
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&flaky_dir);
}

#[test]
fn budget_exhausted_cells_are_done_truncated_and_hash_checked() {
    let dir = tmpdir("budget");
    let mut cfg = base_cfg(&dir);
    cfg.budget = 200;
    cfg.cell_sim_budget = Some(1);
    let out = run_sweep_with(&cfg, &SweepHooks::default()).unwrap();
    assert!(out.failed.is_empty(), "budget exhaustion is not failure");
    assert_eq!(out.rows.len(), 4);
    assert_eq!(out.truncated, 4);
    let m = Manifest::load(&format!("{dir}/manifest.json")).unwrap();
    for e in m.cells.values() {
        assert_eq!(e.status, CellStatus::Done { truncated: true });
        assert!(e.row.as_ref().unwrap().truncated);
    }

    // A resume under a different result-affecting config (no sim budget)
    // must refuse to mix with these manifests.
    let mut incompatible = base_cfg(&dir);
    incompatible.resume = true;
    let err = run_sweep_with(&incompatible, &SweepHooks::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("incompatible"), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
}
