//! Round-trip guarantees for `trace::serde`: a captured trace survives
//! serialize → deserialize with identical structure AND identical
//! simulated behaviour (latency and deadlock verdicts across depth
//! configurations), both in-memory and through a file.

use fifoadvisor::bench_suite;
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::trace::serde::{load, save, trace_from_json, trace_to_json};
use fifoadvisor::util::{Json, Rng};
use std::sync::Arc;

#[test]
fn json_roundtrip_preserves_simulated_latency() {
    let mut rng = Rng::new(99);
    for name in ["fig2", "bicg", "gesummv", "flowgnn_pna", "k7mmseq_balanced"] {
        let bd = bench_suite::build(name);
        let t = collect_trace(&bd.design, &bd.args).unwrap();
        let text = trace_to_json(&t).to_string_compact();
        let t2 = trace_from_json(&Json::parse(&text).unwrap()).unwrap();

        // Structure is preserved.
        assert_eq!(t.design_name, t2.design_name, "{name}");
        assert_eq!(t.total_ops(), t2.total_ops(), "{name}");
        assert_eq!(t.num_fifos(), t2.num_fifos(), "{name}");
        assert_eq!(t.process_names, t2.process_names, "{name}");
        assert_eq!(t.tail_delays, t2.tail_delays, "{name}");
        assert_eq!(t.args, t2.args, "{name}");
        assert_eq!(t.upper_bounds(), t2.upper_bounds(), "{name}");

        // Behaviour is preserved: identical latency/deadlock verdicts on
        // the baselines and on random configurations.
        let ub = t.upper_bounds();
        let mut configs: Vec<Vec<u32>> = vec![t.baseline_max(), t.baseline_min()];
        for _ in 0..6 {
            configs.push(ub.iter().map(|&u| rng.range_u32(2, u.max(2))).collect());
        }
        let mut s1 = FastSim::new(Arc::new(t));
        let mut s2 = FastSim::new(Arc::new(t2));
        for cfg in &configs {
            assert_eq!(
                s1.simulate(cfg).latency(),
                s2.simulate(cfg).latency(),
                "{name}: divergence after round-trip on {cfg:?}"
            );
        }
    }
}

#[test]
fn file_roundtrip_preserves_simulated_latency() {
    let bd = bench_suite::build("gesummv");
    let t = collect_trace(&bd.design, &bd.args).unwrap();
    let path = std::env::temp_dir().join("fifoadvisor_roundtrip_test.json");
    let path = path.to_str().unwrap();
    save(&t, path).unwrap();
    let t2 = load(path).unwrap();
    std::fs::remove_file(path).ok();

    let cfg = t.baseline_max();
    let l1 = FastSim::new(Arc::new(t)).simulate(&cfg).latency();
    let l2 = FastSim::new(Arc::new(t2)).simulate(&cfg).latency();
    assert_eq!(l1, l2);
    assert!(l1.is_some(), "Baseline-Max must be feasible");
}
