//! Differential acceptance for the scenario-set refactor.
//!
//! A single-scenario workload must be **bit-identical** to the raw
//! single-trace path: same outcomes (latency and full deadlock block
//! sets), same channel statistics, same incremental-replay telemetry,
//! and — at the engine level — the same history and counters (modulo
//! timing) for every optimizer, serial and `--jobs 4`. Multi-scenario
//! engines must additionally be deterministic across worker counts.

use fifoadvisor::bench_suite;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::{self, Space};
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::ScenarioSim;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::trace::workload::Workload;
use fifoadvisor::util::prop::suite_with_specials as all_with_specials;
use std::sync::Arc;

#[test]
fn single_scenario_bank_is_bit_identical_to_fastsim_on_every_design() {
    for name in all_with_specials() {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut fast = FastSim::new(t.clone());
        let mut bank = ScenarioSim::single(t.clone());
        let ub = t.upper_bounds();
        // A walk covering cold runs, deadlocks, and 1-channel deltas.
        let mut configs: Vec<Vec<u32>> = vec![t.baseline_max(), t.baseline_min()];
        configs.push(ub.iter().map(|&u| (u / 2).max(2)).collect());
        let mut c = t.baseline_max();
        let mid = c.len() / 2;
        c[mid] = 2;
        configs.push(c.clone());
        c[mid] = ub[mid].max(2);
        configs.push(c);
        for cfg in &configs {
            let a = fast.simulate(cfg);
            let b = bank.simulate(cfg);
            assert_eq!(a, b, "{name}: outcome diverged on {cfg:?}");
            assert_eq!(
                fast.last_run(),
                bank.last_run(),
                "{name}: telemetry diverged on {cfg:?}"
            );
            assert_eq!(bank.scenario_latencies().to_vec(), vec![a.latency()], "{name}");
        }
        // Stats path (the greedy/hunter evaluation mode).
        let (ao, astats) = fast.simulate_with_stats(&t.baseline_max());
        let (bo, bstats) = bank.simulate_with_stats(&t.baseline_max());
        assert_eq!(ao, bo, "{name}");
        assert_eq!(astats.max_occupancy, bstats.max_occupancy, "{name}");
        assert_eq!(astats.write_stall, bstats.write_stall, "{name}");
        assert_eq!(astats.read_stall, bstats.read_stall, "{name}");
    }
}

type HistoryRecord = Vec<(Box<[u32]>, Option<u64>, u32)>;

fn history_of(ev: &Evaluator) -> HistoryRecord {
    ev.history
        .iter()
        .map(|p| (p.depths.clone(), p.latency, p.bram))
        .collect()
}

#[test]
fn workload_single_engine_matches_trace_engine_for_all_optimizers() {
    let bd = bench_suite::build("gesummv");
    let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
    let w = Arc::new(Workload::single(t.clone()));
    let space_t = Space::from_trace(&t);
    let space_w = Space::from_workload(&w);
    assert_eq!(space_t.bounds, space_w.bounds);
    assert_eq!(space_t.per_fifo, space_w.per_fifo);
    for name in opt::OPTIMIZER_NAMES {
        for jobs in [1usize, 4] {
            let mut ev_t = Evaluator::parallel(t.clone(), jobs);
            let mut o = opt::by_name(name, 42).unwrap();
            drive(&mut *o, &mut ev_t, &space_t, 120);
            let mut ev_w = Evaluator::for_workload(w.clone(), jobs);
            let mut o = opt::by_name(name, 42).unwrap();
            drive(&mut *o, &mut ev_w, &space_w, 120);
            assert_eq!(
                history_of(&ev_t),
                history_of(&ev_w),
                "{name} jobs={jobs}: workload-single history diverged"
            );
            // Engine counters, modulo timing.
            let (st, sw) = (ev_t.stats(), ev_w.stats());
            assert_eq!(st.proposals, sw.proposals, "{name} jobs={jobs}");
            assert_eq!(st.cache_hits, sw.cache_hits, "{name} jobs={jobs}");
            assert_eq!(st.sims, sw.sims, "{name} jobs={jobs}");
            assert_eq!(st.incr_sims, sw.incr_sims, "{name} jobs={jobs}");
            assert_eq!(st.replayed_ops, sw.replayed_ops, "{name} jobs={jobs}");
            assert_eq!(st.replayable_ops, sw.replayable_ops, "{name} jobs={jobs}");
            assert_eq!(
                sw.scenario_sims, sw.sims,
                "single-scenario workload: one scenario-sim per sim"
            );
        }
    }
}

#[test]
fn multi_scenario_engine_identical_serial_vs_parallel() {
    let w = Arc::new(bench_suite::build_workload("flowgnn_pna").unwrap());
    assert_eq!(w.num_scenarios(), 4);
    let space = Space::from_workload(&w);
    for name in ["random", "grouped_sa", "greedy", "vitis_hunter"] {
        let mut runs: Vec<HistoryRecord> = Vec::new();
        for jobs in [1usize, 4] {
            let mut ev = Evaluator::for_workload(w.clone(), jobs);
            let mut o = opt::by_name(name, 9).unwrap();
            drive(&mut *o, &mut ev, &space, 90);
            runs.push(history_of(&ev));
        }
        assert_eq!(
            runs[0], runs[1],
            "{name}: multi-scenario serial vs --jobs 4 diverged"
        );
    }
}

#[test]
fn multi_scenario_incremental_replay_engages_in_the_engine() {
    // Serial engine over a 4-graph workload: ±1 single-channel mutation
    // chains must be served as per-scenario delta replays.
    let w = Arc::new(bench_suite::build_workload("flowgnn_pna").unwrap());
    let mut ev = Evaluator::for_workload(w.clone(), 1);
    // Pruning off: this test pins the *exact* unpruned accounting
    // (every sim runs every scenario, no clamp merging); the pruned
    // counterparts live in `pruning_*` below.
    ev.set_prune(false);
    let base = w.baseline_max();
    ev.eval(&base);
    for ch in 0..base.len().min(8) {
        let mut c = base.clone();
        c[ch] -= 1;
        ev.eval(&c);
    }
    let s = ev.stats();
    assert!(s.incr_sims > 0, "no incremental sims on mutation chain: {s:?}");
    assert!(s.replayed_ops < s.replayable_ops, "deltas must save work");
    assert_eq!(s.scenario_sims, s.sims * w.num_scenarios() as u64);
}

// ---------------------------------------------------------------------------
// Simulation-free pruning: identity harness
// ---------------------------------------------------------------------------

fn drive_with_prune(
    engine_of: &dyn Fn() -> Evaluator,
    space: &Space,
    name: &str,
    prune: bool,
    budget: usize,
) -> (HistoryRecord, u64, u64) {
    let mut ev = engine_of();
    ev.set_prune(prune);
    let mut o = opt::by_name(name, 42).unwrap();
    drive(&mut *o, &mut ev, space, budget);
    let s = ev.stats();
    assert_eq!(
        s.cache_hits + s.oracle_hits + s.sims,
        s.proposals,
        "{name} prune={prune}: accounting invariant broken"
    );
    (history_of(&ev), s.sims, s.scenario_sims)
}

#[test]
fn pruning_preserves_histories_for_all_nine_optimizers_single_trace() {
    let bd = bench_suite::build("gesummv");
    let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
    let space = Space::from_trace(&t);
    for name in opt::OPTIMIZER_NAMES {
        let make = || Evaluator::new(t.clone());
        let (on, on_sims, _) = drive_with_prune(&make, &space, name, true, 120);
        let (off, off_sims, _) = drive_with_prune(&make, &space, name, false, 120);
        assert_eq!(
            on, off,
            "{name}: pruned vs unpruned history diverged on gesummv"
        );
        assert!(on_sims <= off_sims, "{name}: pruning must never add sims");
    }
}

#[test]
fn pruning_preserves_histories_for_all_nine_optimizers_on_a_workload() {
    // fig2's 3-scenario workload is deadlock-heavy: the oracle and the
    // early-exit path both engage, and every outcome classification
    // (feasible vs deadlock, per proposal) must survive pruning intact.
    let w = Arc::new(bench_suite::build_workload("fig2").unwrap());
    let space = Space::from_workload(&w);
    for name in opt::OPTIMIZER_NAMES {
        let make = || Evaluator::for_workload(w.clone(), 1);
        let (on, on_sims, on_scen) = drive_with_prune(&make, &space, name, true, 90);
        let (off, off_sims, off_scen) = drive_with_prune(&make, &space, name, false, 90);
        assert_eq!(on, off, "{name}: pruned vs unpruned diverged on fig2 workload");
        assert!(on_sims <= off_sims, "{name}: pruning added sims");
        assert!(on_scen <= off_scen, "{name}: pruning added scenario replays");
    }
}

#[test]
fn pruning_is_identical_serial_vs_parallel_on_clamped_workload() {
    // FlowGNN's designer hints exceed the observed bursts, so the clamp
    // canonicalizer engages; histories must stay identical across
    // prune × jobs.
    let w = Arc::new(bench_suite::build_workload("flowgnn_pna").unwrap());
    let space = Space::from_workload(&w);
    for name in ["random", "grouped_sa", "greedy", "vitis_hunter"] {
        let mut records: Vec<HistoryRecord> = Vec::new();
        for prune in [true, false] {
            for jobs in [1usize, 4] {
                let mut ev = Evaluator::for_workload(w.clone(), jobs);
                ev.set_prune(prune);
                let mut o = opt::by_name(name, 9).unwrap();
                drive(&mut *o, &mut ev, &space, 60);
                if prune && jobs == 1 {
                    assert!(
                        ev.stats().clamp_hits > 0,
                        "{name}: hinted bounds above the bursts must clamp"
                    );
                }
                records.push(history_of(&ev));
            }
        }
        for r in &records[1..] {
            assert_eq!(&records[0], r, "{name}: prune/jobs grid diverged");
        }
    }
}
