//! Differential acceptance for the scenario-set refactor.
//!
//! A single-scenario workload must be **bit-identical** to the raw
//! single-trace path: same outcomes (latency and full deadlock block
//! sets), same channel statistics, same incremental-replay telemetry,
//! and — at the engine level — the same history and counters (modulo
//! timing) for every optimizer, serial and `--jobs 4`. Multi-scenario
//! engines must additionally be deterministic across worker counts, and
//! the simulation-free layers (pruning, analytic bounds) must change
//! only costs, never results — pinned by the prune × bounds × jobs ×
//! backend grids below.

use fifoadvisor::bench_suite;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::{self, Space};
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::{BackendKind, ScenarioSim};
use fifoadvisor::trace::collect_trace;
use fifoadvisor::trace::workload::Workload;
use fifoadvisor::util::prop::suite_with_specials as all_with_specials;
use std::sync::Arc;

#[test]
fn single_scenario_bank_is_bit_identical_to_fastsim_on_every_design() {
    for name in all_with_specials() {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut fast = FastSim::new(t.clone());
        let mut bank = ScenarioSim::single(t.clone());
        let ub = t.upper_bounds();
        // A walk covering cold runs, deadlocks, and 1-channel deltas.
        let mut configs: Vec<Vec<u32>> = vec![t.baseline_max(), t.baseline_min()];
        configs.push(ub.iter().map(|&u| (u / 2).max(2)).collect());
        let mut c = t.baseline_max();
        let mid = c.len() / 2;
        c[mid] = 2;
        configs.push(c.clone());
        c[mid] = ub[mid].max(2);
        configs.push(c);
        for cfg in &configs {
            let a = fast.simulate(cfg);
            let b = bank.simulate(cfg);
            assert_eq!(a, b, "{name}: outcome diverged on {cfg:?}");
            assert_eq!(
                fast.last_run(),
                bank.last_run(),
                "{name}: telemetry diverged on {cfg:?}"
            );
            assert_eq!(bank.scenario_latencies().to_vec(), vec![a.latency()], "{name}");
        }
        // Stats path (the greedy/hunter evaluation mode).
        let (ao, astats) = fast.simulate_with_stats(&t.baseline_max());
        let (bo, bstats) = bank.simulate_with_stats(&t.baseline_max());
        assert_eq!(ao, bo, "{name}");
        assert_eq!(astats.max_occupancy, bstats.max_occupancy, "{name}");
        assert_eq!(astats.write_stall, bstats.write_stall, "{name}");
        assert_eq!(astats.read_stall, bstats.read_stall, "{name}");
    }
}

type HistoryRecord = Vec<(Box<[u32]>, Option<u64>, u32)>;

fn history_of(ev: &Evaluator) -> HistoryRecord {
    ev.history
        .iter()
        .map(|p| (p.depths.clone(), p.latency, p.bram))
        .collect()
}

#[test]
fn workload_single_engine_matches_trace_engine_for_all_optimizers() {
    let bd = bench_suite::build("gesummv");
    let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
    let w = Arc::new(Workload::single(t.clone()));
    let space_t = Space::from_trace(&t);
    let space_w = Space::from_workload(&w);
    assert_eq!(space_t.bounds, space_w.bounds);
    assert_eq!(space_t.per_fifo, space_w.per_fifo);
    for name in opt::OPTIMIZER_NAMES {
        for jobs in [1usize, 4] {
            let mut ev_t = Evaluator::parallel(t.clone(), jobs);
            let mut o = opt::by_name(name, 42).unwrap();
            drive(&mut *o, &mut ev_t, &space_t, 120);
            let mut ev_w = Evaluator::for_workload(w.clone(), jobs);
            let mut o = opt::by_name(name, 42).unwrap();
            drive(&mut *o, &mut ev_w, &space_w, 120);
            assert_eq!(
                history_of(&ev_t),
                history_of(&ev_w),
                "{name} jobs={jobs}: workload-single history diverged"
            );
            // Engine counters, modulo timing.
            let (st, sw) = (ev_t.stats(), ev_w.stats());
            assert_eq!(st.proposals, sw.proposals, "{name} jobs={jobs}");
            assert_eq!(st.cache_hits, sw.cache_hits, "{name} jobs={jobs}");
            assert_eq!(st.sims, sw.sims, "{name} jobs={jobs}");
            assert_eq!(st.incr_sims, sw.incr_sims, "{name} jobs={jobs}");
            assert_eq!(st.replayed_ops, sw.replayed_ops, "{name} jobs={jobs}");
            assert_eq!(st.replayable_ops, sw.replayable_ops, "{name} jobs={jobs}");
            assert_eq!(
                sw.scenario_sims, sw.sims,
                "single-scenario workload: one scenario-sim per sim"
            );
        }
    }
}

#[test]
fn multi_scenario_engine_identical_serial_vs_parallel() {
    let w = Arc::new(bench_suite::build_workload("flowgnn_pna").unwrap());
    assert_eq!(w.num_scenarios(), 4);
    let space = Space::from_workload(&w);
    for name in ["random", "grouped_sa", "greedy", "vitis_hunter"] {
        let mut runs: Vec<HistoryRecord> = Vec::new();
        for jobs in [1usize, 4] {
            let mut ev = Evaluator::for_workload(w.clone(), jobs);
            let mut o = opt::by_name(name, 9).unwrap();
            drive(&mut *o, &mut ev, &space, 90);
            runs.push(history_of(&ev));
        }
        assert_eq!(
            runs[0], runs[1],
            "{name}: multi-scenario serial vs --jobs 4 diverged"
        );
    }
}

#[test]
fn multi_scenario_incremental_replay_engages_in_the_engine() {
    // Serial engine over a 4-graph workload: ±1 single-channel mutation
    // chains must be served as per-scenario delta replays.
    let w = Arc::new(bench_suite::build_workload("flowgnn_pna").unwrap());
    let mut ev = Evaluator::for_workload(w.clone(), 1);
    // Pruning off: this test pins the *exact* unpruned accounting
    // (every sim runs every scenario, no clamp merging); the pruned
    // counterparts live in `pruning_*` below.
    ev.set_prune(false);
    let base = w.baseline_max();
    ev.eval(&base);
    for ch in 0..base.len().min(8) {
        let mut c = base.clone();
        c[ch] -= 1;
        ev.eval(&c);
    }
    let s = ev.stats();
    assert!(s.incr_sims > 0, "no incremental sims on mutation chain: {s:?}");
    assert!(s.replayed_ops < s.replayable_ops, "deltas must save work");
    assert_eq!(s.scenario_sims, s.sims * w.num_scenarios() as u64);
}

// ---------------------------------------------------------------------------
// Simulation-free layers (pruning, analytic bounds): identity harness
// ---------------------------------------------------------------------------

fn drive_with_layers(
    engine_of: &dyn Fn() -> Evaluator,
    space: &Space,
    name: &str,
    prune: bool,
    bounds: bool,
    budget: usize,
) -> (HistoryRecord, u64, u64) {
    let mut ev = engine_of();
    ev.set_prune(prune);
    ev.set_bounds(bounds);
    let mut o = opt::by_name(name, 42).unwrap();
    drive(&mut *o, &mut ev, space, budget);
    let s = ev.stats();
    assert_eq!(
        s.cache_hits + s.oracle_hits + s.sims,
        s.proposals,
        "{name} prune={prune} bounds={bounds}: accounting invariant broken"
    );
    if !bounds {
        assert_eq!(
            s.bounds_floor_hits, 0,
            "{name}: floor hits with the bounds layer off"
        );
        assert_eq!(
            s.cap_tightenings, 0,
            "{name}: tightenings reported with the bounds layer off"
        );
    }
    (history_of(&ev), s.sims, s.scenario_sims)
}

#[test]
fn prune_bounds_grid_preserves_histories_for_all_nine_optimizers_single_trace() {
    let bd = bench_suite::build("gesummv");
    let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
    let space = Space::from_trace(&t);
    for name in opt::OPTIMIZER_NAMES {
        let make = || Evaluator::new(t.clone());
        // Arm order: (bounds, prune) = (T,T), (T,F), (F,T), (F,F).
        let mut records: Vec<HistoryRecord> = Vec::new();
        let mut sims: Vec<u64> = Vec::new();
        for bounds in [true, false] {
            for prune in [true, false] {
                let (h, s, _) = drive_with_layers(&make, &space, name, prune, bounds, 120);
                records.push(h);
                sims.push(s);
            }
        }
        for r in &records[1..] {
            assert_eq!(
                &records[0], r,
                "{name}: prune × bounds history diverged on gesummv"
            );
        }
        assert!(
            sims[0] <= sims[2] && sims[1] <= sims[3],
            "{name}: bounds must never add sims"
        );
        assert!(
            sims[0] <= sims[1] && sims[2] <= sims[3],
            "{name}: pruning must never add sims"
        );
    }
}

#[test]
fn prune_bounds_grid_preserves_histories_for_all_nine_optimizers_on_a_workload() {
    // fig2's 3-scenario workload is deadlock-heavy: the oracle, the
    // early-exit path, and the analytic floor (x needs n − 1 slots) all
    // engage, and every outcome classification (feasible vs deadlock,
    // per proposal) must survive both simulation-free layers intact.
    let w = Arc::new(bench_suite::build_workload("fig2").unwrap());
    let space = Space::from_workload(&w);
    for name in opt::OPTIMIZER_NAMES {
        let make = || Evaluator::for_workload(w.clone(), 1);
        let mut records: Vec<HistoryRecord> = Vec::new();
        let mut costs: Vec<(u64, u64)> = Vec::new();
        for bounds in [true, false] {
            for prune in [true, false] {
                let (h, s, scen) = drive_with_layers(&make, &space, name, prune, bounds, 90);
                records.push(h);
                costs.push((s, scen));
            }
        }
        for r in &records[1..] {
            assert_eq!(
                &records[0], r,
                "{name}: prune × bounds diverged on fig2 workload"
            );
        }
        assert!(
            costs[0].0 <= costs[2].0 && costs[1].0 <= costs[3].0,
            "{name}: bounds added sims"
        );
        assert!(
            costs[0].1 <= costs[2].1 && costs[1].1 <= costs[3].1,
            "{name}: bounds added scenario replays"
        );
        assert!(
            costs[0].0 <= costs[1].0 && costs[2].0 <= costs[3].0,
            "{name}: pruning added sims"
        );
    }
}

#[test]
fn prune_bounds_jobs_grid_is_identical_on_clamped_workload() {
    // FlowGNN's designer hints exceed the observed bursts, so the clamp
    // canonicalizer engages; histories must stay identical across
    // prune × bounds × jobs.
    let w = Arc::new(bench_suite::build_workload("flowgnn_pna").unwrap());
    let space = Space::from_workload(&w);
    for name in ["random", "grouped_sa", "greedy", "vitis_hunter"] {
        let mut records: Vec<HistoryRecord> = Vec::new();
        for bounds in [true, false] {
            for prune in [true, false] {
                for jobs in [1usize, 4] {
                    let mut ev = Evaluator::for_workload(w.clone(), jobs);
                    ev.set_prune(prune);
                    ev.set_bounds(bounds);
                    let mut o = opt::by_name(name, 9).unwrap();
                    drive(&mut *o, &mut ev, &space, 60);
                    if prune && jobs == 1 {
                        assert!(
                            ev.stats().clamp_hits > 0,
                            "{name}: hinted bounds above the bursts must clamp"
                        );
                    }
                    records.push(history_of(&ev));
                }
            }
        }
        for r in &records[1..] {
            assert_eq!(&records[0], r, "{name}: prune/bounds/jobs grid diverged");
        }
    }
}

#[test]
fn bounds_identity_holds_on_every_backend_and_worker_count() {
    // The bounds toggle must be invisible on every simulation backend:
    // same histories across fast / compiled / batched × bounds × jobs,
    // with the floor short-circuit actually firing on the bounded arms
    // (fig2's Baseline-Min sits below the analytic x floor of n − 1).
    let w = Arc::new(bench_suite::build_workload("fig2").unwrap());
    let space = Space::from_workload(&w);
    let backends = [BackendKind::Fast, BackendKind::Compiled, BackendKind::Batched];
    for name in ["greedy", "grouped_sa", "vitis_hunter"] {
        let mut records: Vec<HistoryRecord> = Vec::new();
        let mut serial_sims: Vec<(bool, u64)> = Vec::new();
        for backend in backends {
            for bounds in [true, false] {
                for jobs in [1usize, 4] {
                    let mut ev = Evaluator::for_workload_with_sim(w.clone(), jobs, backend);
                    ev.set_bounds(bounds);
                    // A sub-floor probe, identical in every arm: the
                    // bounded arms answer it analytically, the unbounded
                    // arms simulate — the recorded point must not differ.
                    ev.eval(&w.baseline_min());
                    let mut o = opt::by_name(name, 7).unwrap();
                    drive(&mut *o, &mut ev, &space, 60);
                    let s = ev.stats();
                    if bounds {
                        assert!(
                            s.bounds_floor_hits >= 1,
                            "{name} {}: sub-floor probe missed the short-circuit",
                            backend.name()
                        );
                    } else {
                        assert_eq!(s.bounds_floor_hits, 0, "{name}: hits with bounds off");
                    }
                    if jobs == 1 {
                        serial_sims.push((bounds, s.sims));
                    }
                    records.push(history_of(&ev));
                }
            }
        }
        for r in &records[1..] {
            assert_eq!(
                &records[0], r,
                "{name}: backend × bounds × jobs grid diverged"
            );
        }
        // Per backend the serial arms pair up as (on, off): the analytic
        // answer to the sub-floor probe means the bounded arm can never
        // be more expensive.
        for pair in serial_sims.chunks(2) {
            let (on, off) = (pair[0], pair[1]);
            assert!(on.0 && !off.0, "{name}: arm ordering changed");
            assert!(on.1 <= off.1, "{name}: bounds added sims");
        }
    }
}
