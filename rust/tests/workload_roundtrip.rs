//! Workload JSON serde: save/load round-trip of a multi-scenario
//! workload, plus rejection of malformed scenario sets (mismatched
//! channel topology, wrong arg counts, corrupt JSON).

use fifoadvisor::bench_suite;
use fifoadvisor::ir::{DesignBuilder, Expr};
use fifoadvisor::sim::ScenarioSim;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::trace::workload::{Scenario, Workload, WorkloadError};
use fifoadvisor::util::Json;
use std::sync::Arc;

#[test]
fn multi_scenario_file_roundtrip_preserves_simulation() {
    let w = bench_suite::build_workload("flowgnn_pna").unwrap();
    assert_eq!(w.num_scenarios(), 4);
    let path = "/tmp/fifoadvisor_workload_roundtrip.json";
    w.save(path).unwrap();
    let w2 = Workload::load(path).unwrap();
    std::fs::remove_file(path).ok();

    assert_eq!(w2.design_name(), w.design_name());
    assert_eq!(w2.num_scenarios(), w.num_scenarios());
    assert_eq!(w2.upper_bounds(), w.upper_bounds());
    for (a, b) in w.scenarios().iter().zip(w2.scenarios()) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.weight, b.weight);
        assert_eq!(a.trace.args, b.trace.args);
        assert_eq!(a.trace.total_ops(), b.trace.total_ops());
    }
    // The reloaded workload simulates identically (worst-case outcome
    // and per-scenario latencies) on baselines and a mid config.
    let mid: Vec<u32> = w.upper_bounds().iter().map(|&u| (u / 2).max(2)).collect();
    let mut s1 = ScenarioSim::new(&w);
    let mut s2 = ScenarioSim::new(&w2);
    for cfg in [w.baseline_max(), w.baseline_min(), mid] {
        assert_eq!(s1.simulate(&cfg), s2.simulate(&cfg), "cfg {cfg:?}");
        assert_eq!(s1.scenario_latencies(), s2.scenario_latencies());
    }
}

#[test]
fn wrong_arg_count_rejected() {
    let bd = bench_suite::build("flowgnn_pna");
    // flowgnn_pna takes 3 args; the second scenario passes 2.
    let err = Workload::from_design(
        &bd.design,
        &[
            ("ok".into(), vec![64, 512, 7]),
            ("short".into(), vec![64, 512]),
        ],
    )
    .unwrap_err();
    match err {
        WorkloadError::ArgCount {
            scenario,
            expected,
            got,
            ..
        } => {
            assert_eq!(scenario, "short");
            assert_eq!(expected, 3);
            assert_eq!(got, 2);
        }
        other => panic!("expected ArgCount, got {other}"),
    }
}

#[test]
fn mismatched_channel_topology_rejected() {
    // Two designs with the same name but different channel widths: the
    // traces cannot form one workload.
    let mk = |wbits: u32| {
        let mut b = DesignBuilder::new("topo", 0);
        let c = b.channel("c", wbits);
        b.process("p", move |p| p.write(c, Expr::c(0)));
        b.process("q", move |p| {
            let _ = p.read(c);
        });
        b.build()
    };
    let t32 = Arc::new(collect_trace(&mk(32), &[]).unwrap());
    let t64 = Arc::new(collect_trace(&mk(64), &[]).unwrap());
    let err = Workload::new(vec![
        Scenario {
            name: "a".into(),
            weight: 1.0,
            trace: t32.clone(),
        },
        Scenario {
            name: "b".into(),
            weight: 1.0,
            trace: t64,
        },
    ])
    .unwrap_err();
    assert!(matches!(err, WorkloadError::TopologyMismatch { .. }), "{err}");

    // Different channel count is also a topology mismatch.
    let mut b = DesignBuilder::new("topo", 0);
    let c = b.channel("c", 32);
    let d = b.channel("d", 32);
    b.process("p", move |p| {
        p.write(c, Expr::c(0));
        p.write(d, Expr::c(0));
    });
    b.process("q", move |p| {
        let _ = p.read(c);
        let _ = p.read(d);
    });
    let t2 = Arc::new(collect_trace(&b.build(), &[]).unwrap());
    let err = Workload::new(vec![
        Scenario {
            name: "a".into(),
            weight: 1.0,
            trace: t32,
        },
        Scenario {
            name: "b".into(),
            weight: 1.0,
            trace: t2,
        },
    ])
    .unwrap_err();
    assert!(matches!(err, WorkloadError::TopologyMismatch { .. }), "{err}");
}

#[test]
fn corrupt_workload_json_rejected() {
    assert!(Workload::from_json(&Json::Null).is_err());
    assert!(Workload::from_json(&Json::obj(vec![(
        "scenarios",
        Json::Arr(vec![])
    )]))
    .is_err());
    // A scenario entry without a trace.
    let j = Json::obj(vec![(
        "scenarios",
        Json::Arr(vec![Json::obj(vec![("name", Json::Str("x".into()))])]),
    )]);
    assert!(Workload::from_json(&j).is_err());
    // Design-name disagreement between header and traces.
    let w = bench_suite::build_workload("fig2").unwrap();
    let mut text = w.to_json().to_string_compact();
    text = text.replacen("\"design_name\":\"fig2\"", "\"design_name\":\"other\"", 1);
    let j = Json::parse(&text).unwrap();
    assert!(Workload::from_json(&j).is_err());
}
